//! Persistent compute-thread pool backing the backend fan-out drivers.
//!
//! Before this module existed every sizable `Backend` call paid a
//! `std::thread::scope` spawn: ~100µs+ of thread creation and teardown
//! per GEMM, on top of whatever the kernel itself cost, and each call
//! competed blindly with the serving shards for cores.  The pool
//! replaces that with workers spawned once per process (sized by
//! [`crate::linalg::backend::thread_budget`]) that park on a condvar
//! between calls; handing a batch of band tasks to a parked worker is a
//! mutex push + wake, microseconds instead of spawns.
//!
//! ## Execution model
//!
//! [`ComputePool::run`] submits one *batch* — `tasks` indices, each
//! handed exactly once to the task closure — then the **calling thread
//! participates**: it claims indices alongside the workers and only
//! waits once the batch is fully claimed.  That keeps two properties
//! the backends rely on:
//!
//! * **No deadlock on nesting.**  A band task that itself calls
//!   `run` (e.g. a tree-build band invoking a threaded SYRK) makes
//!   progress even if every worker is busy, because the submitter
//!   drains its own batch.
//! * **Borrowed data is safe.**  `run` blocks until every claimed index
//!   has finished, so the task closure may borrow stack data; the
//!   lifetime-erased pointer handed to workers is never dereferenced
//!   after `run` returns (a fully-claimed batch is popped, and stale
//!   entries are only ever popped, not executed).
//!
//! Band *partitioning* stays with the caller ([`fan_out_rows`] computes
//! the same deterministic row bands as the old spawn path), so moving
//! to the pool cannot change which elements are accumulated in which
//! order — the determinism contract survives by construction.
//!
//! Worker panics are caught per index ([`std::panic::catch_unwind`]),
//! recorded on the batch, and re-raised on the submitting thread once
//! the batch completes — the same containment the old
//! `thread::scope` path provided via join, without poisoning the
//! long-lived workers.
//!
//! [`fan_out_rows`]: crate::linalg::backend::fan_out_rows

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Lifetime-erased pointer to a batch's task closure.  Constructed only
/// inside [`ComputePool::run`], which keeps the closure alive (and the
/// submitting thread blocked) until every index has finished.
struct TaskRef(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (it is called concurrently from many
// workers by design) and `run` guarantees it outlives every
// dereference, so shipping the pointer across threads is sound.
unsafe impl Send for TaskRef {}
unsafe impl Sync for TaskRef {}

impl TaskRef {
    /// Erase the closure's borrow lifetime so workers can hold the
    /// pointer.
    ///
    /// # Safety
    /// The caller must keep `task` alive and in place until the batch's
    /// `pending` count reaches zero — [`ComputePool::run`] does so by
    /// blocking on `done_cv` before returning.
    unsafe fn erase<'a>(task: &'a (dyn Fn(usize) + Sync + 'a)) -> TaskRef {
        let short: *const (dyn Fn(usize) + Sync + 'a) = task;
        TaskRef(std::mem::transmute::<
            *const (dyn Fn(usize) + Sync + 'a),
            *const (dyn Fn(usize) + Sync + 'static),
        >(short))
    }
}

/// One `run` call: a fixed number of task indices claimed atomically by
/// whichever threads get there first.
struct Batch {
    task: TaskRef,
    /// Number of task indices in the batch.
    total: usize,
    /// Next unclaimed index; claims past `total` mean "exhausted".
    next: AtomicUsize,
    /// Indices claimed but not yet finished, initially `total`.
    pending: AtomicUsize,
    /// Set when any index panicked; re-raised by the submitter.
    poisoned: AtomicBool,
    /// Pairs with `done_cv`: the submitter waits here for `pending == 0`.
    done: Mutex<()>,
    done_cv: Condvar,
}

impl Batch {
    /// Claim the next unexecuted index, or `None` if the batch is
    /// exhausted.
    fn claim(&self) -> Option<usize> {
        // Relaxed is enough: the index values carry no data dependency
        // (task inputs were published by the queue mutex) and
        // fetch_add already serializes claimants.
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        if i < self.total {
            Some(i)
        } else {
            None
        }
    }

    /// Run one claimed index, containing panics, and signal the
    /// submitter when the batch drains.
    fn run_index(&self, i: usize) {
        // SAFETY: `ComputePool::run` keeps the closure alive until
        // `pending` reaches zero, which cannot happen before this call
        // completes (our decrement below is what releases it).
        let task = unsafe { &*self.task.0 };
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(i))).is_ok();
        if !ok {
            self.poisoned.store(true, Ordering::Relaxed);
        }
        // Release pairs with the submitter's Acquire load: its read of
        // pending == 0 makes every task's writes (band output rows)
        // visible before `run` returns.
        if self.pending.fetch_sub(1, Ordering::Release) == 1 {
            let _guard = self.done.lock().expect("compute pool batch mutex poisoned");
            self.done_cv.notify_all();
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<Arc<Batch>>>,
    work_cv: Condvar,
}

/// A fixed set of persistent worker threads executing index batches.
///
/// Obtain the process-wide instance through [`global`]; constructing
/// additional pools is possible (tests size their own) but each pool
/// spawns its own OS threads.
pub struct ComputePool {
    shared: Arc<Shared>,
    workers: usize,
}

impl ComputePool {
    /// Spawn `workers` parked worker threads.  The submitting thread
    /// participates in every batch, so a pool sized `N-1` saturates `N`
    /// cores.
    pub fn new(workers: usize) -> ComputePool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
        });
        for w in 0..workers {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("ndpp-compute-{w}"))
                .spawn(move || worker_loop(&shared))
                .expect("failed to spawn compute pool worker");
        }
        ComputePool { shared, workers }
    }

    /// Number of worker threads (excluding the participating caller).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute `task(0..tasks)`, each index exactly once, across the
    /// workers and the calling thread; returns when all have finished.
    ///
    /// Panics if any task panicked (after the whole batch has drained,
    /// so sibling bands are never abandoned half-written).
    pub fn run(&self, tasks: usize, task: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        if tasks == 1 || self.workers == 0 {
            for i in 0..tasks {
                task(i);
            }
            return;
        }
        // SAFETY: this frame blocks on `done_cv` until `pending == 0`,
        // i.e. until no thread can touch the pointer again.
        let erased = unsafe { TaskRef::erase(task) };
        let batch = Arc::new(Batch {
            task: erased,
            total: tasks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(tasks),
            poisoned: AtomicBool::new(false),
            done: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        {
            let mut queue = self
                .shared
                .queue
                .lock()
                .expect("compute pool queue poisoned");
            queue.push_back(Arc::clone(&batch));
        }
        self.shared.work_cv.notify_all();
        // Participate: drain indices alongside the workers.
        while let Some(i) = batch.claim() {
            batch.run_index(i);
        }
        let mut guard = batch.done.lock().expect("compute pool batch mutex poisoned");
        while batch.pending.load(Ordering::Acquire) > 0 {
            guard = batch
                .done_cv
                .wait(guard)
                .expect("compute pool batch mutex poisoned");
        }
        drop(guard);
        if batch.poisoned.load(Ordering::Relaxed) {
            panic!("backend worker panicked");
        }
    }
}

/// Worker body: claim indices from the front batch, pop exhausted
/// batches, park when the queue is empty.  Workers live for the process
/// lifetime (the global pool is never torn down), so there is no
/// shutdown path.
fn worker_loop(shared: &Shared) {
    let mut queue = shared.queue.lock().expect("compute pool queue poisoned");
    loop {
        if let Some(front) = queue.front() {
            if let Some(i) = front.claim() {
                let batch = Arc::clone(front);
                drop(queue);
                batch.run_index(i);
                queue = shared.queue.lock().expect("compute pool queue poisoned");
            } else {
                // Exhausted: every index is claimed (the claimants are
                // responsible for finishing them); retire the batch.
                queue.pop_front();
            }
        } else {
            queue = shared
                .work_cv
                .wait(queue)
                .expect("compute pool queue poisoned");
        }
    }
}

/// The process-wide pool, created on first use with
/// [`thread_budget().pool_workers`](crate::linalg::backend::thread_budget)
/// workers (the submitting thread supplies the remaining band, so the
/// fan-out width stays `thread_budget().backend`).
pub fn global() -> &'static ComputePool {
    static POOL: std::sync::OnceLock<ComputePool> = std::sync::OnceLock::new();
    POOL.get_or_init(|| ComputePool::new(super::backend::thread_budget().pool_workers))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = ComputePool::new(3);
        for tasks in [0usize, 1, 2, 3, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.run(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} of {tasks}");
            }
        }
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = ComputePool::new(0);
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        pool.run(5, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn nested_run_makes_progress() {
        // A task that submits its own batch must not deadlock even when
        // the outer batch occupies every worker.
        let pool = ComputePool::new(2);
        let inner_hits = AtomicUsize::new(0);
        pool.run(8, &|_| {
            pool.run(4, &|_| {
                inner_hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(inner_hits.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn sequential_batches_reuse_workers() {
        let pool = ComputePool::new(2);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(6, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 300);
    }

    #[test]
    #[should_panic(expected = "backend worker panicked")]
    fn task_panic_propagates_to_submitter() {
        let pool = ComputePool::new(2);
        pool.run(4, &|i| {
            if i == 2 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_poisoned_batch() {
        let pool = ComputePool::new(2);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|_| panic!("boom"));
        }));
        assert!(outcome.is_err());
        // Workers must still be alive and serving.
        let total = AtomicUsize::new(0);
        pool.run(16, &|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn global_pool_matches_thread_budget() {
        let budget = crate::linalg::backend::thread_budget();
        assert_eq!(global().workers(), budget.pool_workers);
    }
}
