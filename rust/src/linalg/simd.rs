//! Runtime-dispatched SIMD microkernels — the instruction-level floor
//! of [`crate::linalg::backend::SimdBackend`].
//!
//! Four implementations of the same small kernel set live here, selected
//! once per process by probing the CPU:
//!
//! * **AVX-512F** (`x86_64`) — `_mm512_*` intrinsics: 8-lane `f64x8`
//!   GEMM tiles with fused multiply-add.  Chosen when
//!   `is_x86_feature_detected!("avx512f")` holds (plus AVX2+FMA, which
//!   the BLAS-1 kernels keep using — see below).
//! * **AVX2 + FMA** (`x86_64`) — `_mm256_*` intrinsics: 4-lane `f64x4`
//!   vectors with fused multiply-add.  Chosen when
//!   `is_x86_feature_detected!("avx2")` *and* `("fma")` both hold.
//! * **NEON** (`aarch64`) — `vfmaq_f64` over `f64x2` pairs, two vectors
//!   per step so the kernels stay 4-wide.  NEON is part of the aarch64
//!   baseline, so no runtime probe is needed.
//! * **Portable** — plain-Rust loops with the same 4-wide lane structure
//!   (independent partial accumulators, lanes summed as
//!   `(l0 + l2) + (l1 + l3)`), used on every other CPU.  LLVM
//!   autovectorizes what it can; correctness never depends on that.
//!
//! `NDPP_SIMD_ISA` (`auto`, `portable`, `avx2`, `avx512`, `neon`)
//! overrides the probe, read once per process; requesting an ISA the
//! CPU does not support falls back to the probed best, so the safety
//! invariant below survives misconfiguration.  The CI backend matrix
//! uses `NDPP_SIMD_ISA=portable` to exercise the fallback lanes on
//! hardware that would otherwise always take an intrinsic path.
//!
//! The kernel set is deliberately tiny — `axpy` (`y += a * x`), `dot`,
//! `gemm4` (the 4-row register-tiled GEMM panel update), and its
//! packed-panel sibling `pack_b`/`gemm4_packed` (same arithmetic, B
//! pre-packed into contiguous `NR`-column micro-panels so the inner
//! loop streams unit-stride loads) — because every `Backend` primitive
//! decomposes into those plus control flow that lives in `backend.rs`.
//!
//! **Determinism & equivalence.** For each output element every kernel
//! accumulates in ascending index order, exactly like the scalar
//! backends; vector paths differ from scalar only by lane regrouping of
//! reductions and by FMA's single rounding, both bounded far below the
//! 1e-10 the equivalence suite enforces.  Lane *width* never enters:
//! `gemm4` performs one FMA per `(element, dk)` pair regardless of how
//! many columns share a vector, so the AVX-512 tier agrees with AVX2
//! bitwise on vector-covered columns, and `gemm4_packed` is bitwise
//! identical to `gemm4` per ISA.  The AVX-512 tier deliberately keeps
//! `axpy`/`dot` on the AVX2 kernels (the probe requires AVX2+FMA) so
//! the documented 4-lane reduction grouping is identical across the two
//! tiers.  Repeated runs on the same machine are bitwise identical (the
//! ISA never changes under a process).
//!
//! **Safety.** The unsafe intrinsic paths are only reachable through
//! [`Kernels`], whose ISA field is private and can only be populated by
//! [`Kernels::detect`] (probes the CPU) or [`Kernels::portable`] (no
//! unsafe at all) — so an AVX2 or AVX-512 kernel can never be invoked
//! on a CPU that did not report the feature.  Every kernel bounds its
//! loops by the slice lengths it receives; `gemm4`/`gemm4_packed`
//! validate their panel geometry up front.

use std::sync::OnceLock;

/// Instruction set driving the microkernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// AVX-512F `_mm512_*` f64x8 GEMM tiles (x86_64); BLAS-1 stays on
    /// the AVX2 kernels, which the probe also requires.
    Avx512,
    /// AVX2 + FMA `_mm256_*` f64x4 intrinsics (x86_64).
    Avx2,
    /// NEON `vfmaq_f64` f64x2 pairs (aarch64 baseline).
    Neon,
    /// 4-wide lane-structured scalar loops — the fallback on CPUs
    /// without AVX-512/AVX2/FMA, and the reference the intrinsic paths
    /// are tested against.
    Portable,
}

impl Isa {
    pub fn as_str(&self) -> &'static str {
        match self {
            Isa::Avx512 => "avx512",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
            Isa::Portable => "portable",
        }
    }
}

/// Resolve the ISA once per process: probe the CPU, then apply the
/// `NDPP_SIMD_ISA` override (if set) against what the probe actually
/// found.  Cached so repeated [`Kernels::detect`] calls never re-read
/// the environment.
fn detect_isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(|| {
        let request = std::env::var("NDPP_SIMD_ISA").ok();
        resolve_isa(request.as_deref())
    })
}

/// Apply an `NDPP_SIMD_ISA` request against the probed capability set.
/// `portable` is always honored; any other requested ISA is honored
/// only if the CPU supports it (otherwise the probed best wins, so an
/// intrinsic path can never run on hardware that lacks it).
fn resolve_isa(request: Option<&str>) -> Isa {
    let probed = probe_isa();
    let want = match request {
        None | Some("") | Some("auto") => return probed,
        Some("portable") => return Isa::Portable,
        Some("avx512") => Isa::Avx512,
        Some("avx2") => Isa::Avx2,
        Some("neon") => Isa::Neon,
        Some(other) => panic!(
            "NDPP_SIMD_ISA: unknown ISA {other:?} \
             (expected auto, portable, avx2, avx512, or neon)"
        ),
    };
    let supported = match want {
        Isa::Portable => true,
        Isa::Avx2 => matches!(probed, Isa::Avx2 | Isa::Avx512),
        Isa::Avx512 => probed == Isa::Avx512,
        Isa::Neon => probed == Isa::Neon,
    };
    if supported {
        want
    } else {
        probed
    }
}

/// Probe the CPU and return the best supported [`Isa`].
fn probe_isa() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        let avx2 = std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma");
        if avx2 && std::arch::is_x86_feature_detected!("avx512f") {
            return Isa::Avx512;
        }
        if avx2 {
            return Isa::Avx2;
        }
        Isa::Portable
    }
    #[cfg(target_arch = "aarch64")]
    {
        Isa::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Isa::Portable
    }
}

/// Dispatch handle for the microkernels.
///
/// The ISA field is private on purpose: [`Kernels::detect`] is the only
/// way to obtain an intrinsic-backed handle, so holding a `Kernels` is
/// proof the instructions it dispatches to exist on this CPU.  (An ISA
/// that does not apply to the compilation target — e.g. `Neon` on
/// x86_64 — dispatches to the portable path.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Kernels {
    isa: Isa,
}

impl Kernels {
    /// Kernels for the best instruction set the CPU reports at runtime.
    pub fn detect() -> Kernels {
        Kernels { isa: detect_isa() }
    }

    /// The portable 4-wide fallback lanes — what [`Kernels::detect`]
    /// selects on hardware without AVX2/FMA (or NEON).  Public so tests
    /// can hold the fallback path to the intrinsic path on the same
    /// machine.
    pub fn portable() -> Kernels {
        Kernels { isa: Isa::Portable }
    }

    /// The instruction set this handle dispatches to.
    pub fn isa(&self) -> Isa {
        self.isa
    }

    /// Width in columns of the packed-B micro-panel block this ISA's
    /// [`Kernels::gemm4_packed`] consumes: 8 on AVX-512, 4 everywhere
    /// else.
    #[inline]
    pub fn nr(&self) -> usize {
        match self.isa {
            Isa::Avx512 => 8,
            _ => 4,
        }
    }

    /// `y[i] += a * x[i]` over the common prefix of `y` and `x`.
    #[inline]
    pub fn axpy(&self, y: &mut [f64], a: f64, x: &[f64]) {
        #[cfg(target_arch = "x86_64")]
        if matches!(self.isa, Isa::Avx2 | Isa::Avx512) {
            // SAFETY: both ISAs are only constructed by detect_isa()
            // after confirming AVX2 and FMA support on this CPU (the
            // AVX-512 probe requires them too).  BLAS-1 stays on the
            // 4-lane AVX2 kernels so the documented lane grouping is
            // identical across the two tiers.
            unsafe { avx2::axpy(y, a, x) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if self.isa == Isa::Neon {
            // SAFETY: NEON is part of the aarch64 baseline.
            unsafe { neon::axpy(y, a, x) };
            return;
        }
        portable::axpy(y, a, x);
    }

    /// Dot product of the common prefix of `a` and `b`, 4 lanes summed
    /// as `(l0 + l2) + (l1 + l3)` plus a sequential tail.
    #[inline]
    pub fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        #[cfg(target_arch = "x86_64")]
        if matches!(self.isa, Isa::Avx2 | Isa::Avx512) {
            // SAFETY: see `axpy`.
            return unsafe { avx2::dot(a, b) };
        }
        #[cfg(target_arch = "aarch64")]
        if self.isa == Isa::Neon {
            // SAFETY: see `axpy`.
            return unsafe { neon::dot(a, b) };
        }
        portable::dot(a, b)
    }

    /// 4-row register-tiled GEMM panel update.
    ///
    /// `c` holds four contiguous output rows of width `n`; for each
    /// column block the four output sub-rows are accumulated in
    /// registers while streaming rows `kk..kend` of the row-major `b`
    /// (width `n`), scaled by the matching entries of the four `a` rows.
    /// Per output element the accumulation order is `dk` ascending —
    /// identical to the scalar backends.
    pub fn gemm4(
        &self,
        c: &mut [f64],
        n: usize,
        a: [&[f64]; 4],
        b: &[f64],
        kk: usize,
        kend: usize,
    ) {
        assert!(c.len() >= 4 * n, "gemm4: c too short for 4 rows of {n}");
        assert!(kk <= kend, "gemm4: inverted k range {kk}..{kend}");
        assert!(b.len() >= kend * n, "gemm4: b too short for {kend} rows of {n}");
        for arow in &a {
            assert!(arow.len() >= kend, "gemm4: a row shorter than kend {kend}");
        }
        #[cfg(target_arch = "x86_64")]
        if self.isa == Isa::Avx512 {
            // SAFETY: Isa::Avx512 is only constructed by detect_isa()
            // after confirming AVX-512F support; geometry validated
            // above.
            unsafe { avx512::gemm4(c, n, a, b, kk, kend) };
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if self.isa == Isa::Avx2 {
            // SAFETY: see `axpy`; geometry validated above.
            unsafe { avx2::gemm4(c, n, a, b, kk, kend) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if self.isa == Isa::Neon {
            // SAFETY: see `axpy`; geometry validated above.
            unsafe { neon::gemm4(c, n, a, b, kk, kend) };
            return;
        }
        portable::gemm4(c, n, a, b, kk, kend);
    }

    /// Pack rows `kk..kend` of the row-major `b` (width `n`) into the
    /// micro-panel layout [`Kernels::gemm4_packed`] reads: `NR`-column
    /// blocks (`NR` = [`Kernels::nr`]), each holding its `kend - kk`
    /// rows contiguously, with the final block zero-padded past column
    /// `n`.  Element `(dk, jb * NR + l)` of the panel lands at
    /// `buf[(jb * (kend - kk) + dk - kk) * NR + l]`.
    ///
    /// `buf` is resized to exactly the panel size and every retained
    /// entry is overwritten, so callers can reuse one buffer across
    /// panels — steady state allocates nothing once the buffer has
    /// grown to the largest panel seen.
    pub fn pack_b(&self, buf: &mut Vec<f64>, b: &[f64], n: usize, kk: usize, kend: usize) {
        assert!(kk <= kend, "pack_b: inverted k range {kk}..{kend}");
        assert!(b.len() >= kend * n, "pack_b: b too short for {kend} rows of {n}");
        let nr = self.nr();
        let kdepth = kend - kk;
        let blocks = n.div_ceil(nr);
        buf.resize(blocks * nr * kdepth, 0.0);
        let full = n / nr;
        for jb in 0..full {
            let col0 = jb * nr;
            let dst0 = jb * kdepth * nr;
            for d in 0..kdepth {
                let src = (kk + d) * n + col0;
                buf[dst0 + d * nr..dst0 + (d + 1) * nr].copy_from_slice(&b[src..src + nr]);
            }
        }
        if full < blocks {
            let col0 = full * nr;
            let dst0 = full * kdepth * nr;
            for d in 0..kdepth {
                for l in 0..nr {
                    let col = col0 + l;
                    buf[dst0 + d * nr + l] = if col < n { b[(kk + d) * n + col] } else { 0.0 };
                }
            }
        }
    }

    /// 4-row register-tiled GEMM panel update reading a packed B panel
    /// produced by [`Kernels::pack_b`] for the same `kk..kend` range.
    ///
    /// Identical arithmetic to [`Kernels::gemm4`] — per output element
    /// one FMA per `dk`, `dk` ascending — so the packed and unpacked
    /// walks are bitwise identical per ISA; only the B access pattern
    /// changes (unit-stride streams through the micro-panels instead of
    /// `n`-strided row walks).
    pub fn gemm4_packed(
        &self,
        c: &mut [f64],
        n: usize,
        a: [&[f64]; 4],
        packed: &[f64],
        kk: usize,
        kend: usize,
    ) {
        let nr = self.nr();
        assert!(c.len() >= 4 * n, "gemm4_packed: c too short for 4 rows of {n}");
        assert!(kk <= kend, "gemm4_packed: inverted k range {kk}..{kend}");
        assert!(
            packed.len() >= n.div_ceil(nr) * nr * (kend - kk),
            "gemm4_packed: panel too short for {} rows of {n} at NR={nr}",
            kend - kk
        );
        for arow in &a {
            assert!(arow.len() >= kend, "gemm4_packed: a row shorter than kend {kend}");
        }
        #[cfg(target_arch = "x86_64")]
        if self.isa == Isa::Avx512 {
            // SAFETY: see `gemm4`; geometry validated above.
            unsafe { avx512::gemm4_packed(c, n, a, packed, kk, kend) };
            return;
        }
        #[cfg(target_arch = "x86_64")]
        if self.isa == Isa::Avx2 {
            // SAFETY: see `axpy`; geometry validated above.
            unsafe { avx2::gemm4_packed(c, n, a, packed, kk, kend) };
            return;
        }
        #[cfg(target_arch = "aarch64")]
        if self.isa == Isa::Neon {
            // SAFETY: see `axpy`; geometry validated above.
            unsafe { neon::gemm4_packed(c, n, a, packed, kk, kend) };
            return;
        }
        portable::gemm4_packed(c, n, a, packed, kk, kend);
    }
}

/// Scalar column tail shared by every `gemm4` implementation: columns
/// `j0..n`, same `dk`-ascending per-element accumulation as the vector
/// body.
fn gemm4_tail(
    c: &mut [f64],
    n: usize,
    a: [&[f64]; 4],
    b: &[f64],
    kk: usize,
    kend: usize,
    j0: usize,
) {
    let [a0, a1, a2, a3] = a;
    for j in j0..n {
        let mut s = [c[j], c[n + j], c[2 * n + j], c[3 * n + j]];
        for dk in kk..kend {
            let bj = b[dk * n + j];
            s[0] += a0[dk] * bj;
            s[1] += a1[dk] * bj;
            s[2] += a2[dk] * bj;
            s[3] += a3[dk] * bj;
        }
        c[j] = s[0];
        c[n + j] = s[1];
        c[2 * n + j] = s[2];
        c[3 * n + j] = s[3];
    }
}

/// Scalar tail shared by every `gemm4_packed` implementation: the final
/// (possibly partial) `nr`-column micro-panel `block` (row stride
/// `nr`), covering output columns `j0..n`.  `a` holds the four A-row
/// segments pre-sliced to the panel's k range, so the packed row index
/// `d` and the A index coincide; per element the accumulation is `d`
/// ascending — the same order as [`gemm4_tail`] walks its columns.
fn gemm4_packed_tail(c: &mut [f64], n: usize, a: [&[f64]; 4], block: &[f64], nr: usize, j0: usize) {
    let [a0, a1, a2, a3] = a;
    let kdepth = a0.len();
    for l in 0..(n - j0) {
        let j = j0 + l;
        let mut s = [c[j], c[n + j], c[2 * n + j], c[3 * n + j]];
        for d in 0..kdepth {
            let bj = block[d * nr + l];
            s[0] += a0[d] * bj;
            s[1] += a1[d] * bj;
            s[2] += a2[d] * bj;
            s[3] += a3[d] * bj;
        }
        c[j] = s[0];
        c[n + j] = s[1];
        c[2 * n + j] = s[2];
        c[3 * n + j] = s[3];
    }
}

// ======================================================================
// Portable lanes — the fallback and the testing reference
// ======================================================================

mod portable {
    use super::{gemm4_packed_tail, gemm4_tail};

    /// `y[i] += a * x[i]` — no reduction, so per-element results match
    /// any vector width; LLVM autovectorizes the zip.
    pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
        for (yi, &xi) in y.iter_mut().zip(x) {
            *yi += a * xi;
        }
    }

    /// Four independent lane accumulators, summed `(l0+l2) + (l1+l3)` —
    /// the same grouping the vector paths use.
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
        let quads = n / 4;
        for q in 0..quads {
            let i = 4 * q;
            s0 += a[i] * b[i];
            s1 += a[i + 1] * b[i + 1];
            s2 += a[i + 2] * b[i + 2];
            s3 += a[i + 3] * b[i + 3];
        }
        let mut s = (s0 + s2) + (s1 + s3);
        for i in 4 * quads..n {
            s += a[i] * b[i];
        }
        s
    }

    /// `t[l] += x * b[l]` over a 4-wide register block.
    #[inline]
    fn fma4(t: &mut [f64; 4], x: f64, b: &[f64]) {
        t[0] += x * b[0];
        t[1] += x * b[1];
        t[2] += x * b[2];
        t[3] += x * b[3];
    }

    /// 4x4 register tile in plain Rust: the same j-block / k-inner
    /// structure as the intrinsic versions.
    pub fn gemm4(c: &mut [f64], n: usize, a: [&[f64]; 4], b: &[f64], kk: usize, kend: usize) {
        let [a0, a1, a2, a3] = a;
        let quads = n / 4;
        for q in 0..quads {
            let j = 4 * q;
            let mut t0 = [0.0f64; 4];
            let mut t1 = [0.0f64; 4];
            let mut t2 = [0.0f64; 4];
            let mut t3 = [0.0f64; 4];
            t0.copy_from_slice(&c[j..j + 4]);
            t1.copy_from_slice(&c[n + j..n + j + 4]);
            t2.copy_from_slice(&c[2 * n + j..2 * n + j + 4]);
            t3.copy_from_slice(&c[3 * n + j..3 * n + j + 4]);
            for dk in kk..kend {
                let bv = &b[dk * n + j..dk * n + j + 4];
                fma4(&mut t0, a0[dk], bv);
                fma4(&mut t1, a1[dk], bv);
                fma4(&mut t2, a2[dk], bv);
                fma4(&mut t3, a3[dk], bv);
            }
            c[j..j + 4].copy_from_slice(&t0);
            c[n + j..n + j + 4].copy_from_slice(&t1);
            c[2 * n + j..2 * n + j + 4].copy_from_slice(&t2);
            c[3 * n + j..3 * n + j + 4].copy_from_slice(&t3);
        }
        gemm4_tail(c, n, [a0, a1, a2, a3], b, kk, kend, 4 * quads);
    }

    /// Packed-panel 4x4 register tile: the same arithmetic as [`gemm4`]
    /// (bitwise), reading the NR=4 micro-panel layout of
    /// [`super::Kernels::pack_b`].
    pub fn gemm4_packed(
        c: &mut [f64],
        n: usize,
        a: [&[f64]; 4],
        packed: &[f64],
        kk: usize,
        kend: usize,
    ) {
        let [a0, a1, a2, a3] = a;
        let kdepth = kend - kk;
        let quads = n / 4;
        for q in 0..quads {
            let j = 4 * q;
            let base = q * kdepth * 4;
            let mut t0 = [0.0f64; 4];
            let mut t1 = [0.0f64; 4];
            let mut t2 = [0.0f64; 4];
            let mut t3 = [0.0f64; 4];
            t0.copy_from_slice(&c[j..j + 4]);
            t1.copy_from_slice(&c[n + j..n + j + 4]);
            t2.copy_from_slice(&c[2 * n + j..2 * n + j + 4]);
            t3.copy_from_slice(&c[3 * n + j..3 * n + j + 4]);
            for d in 0..kdepth {
                let bv = &packed[base + d * 4..base + (d + 1) * 4];
                let dk = kk + d;
                fma4(&mut t0, a0[dk], bv);
                fma4(&mut t1, a1[dk], bv);
                fma4(&mut t2, a2[dk], bv);
                fma4(&mut t3, a3[dk], bv);
            }
            c[j..j + 4].copy_from_slice(&t0);
            c[n + j..n + j + 4].copy_from_slice(&t1);
            c[2 * n + j..2 * n + j + 4].copy_from_slice(&t2);
            c[3 * n + j..3 * n + j + 4].copy_from_slice(&t3);
        }
        if 4 * quads < n {
            let tail = [&a0[kk..kend], &a1[kk..kend], &a2[kk..kend], &a3[kk..kend]];
            gemm4_packed_tail(c, n, tail, &packed[quads * kdepth * 4..], 4, 4 * quads);
        }
    }
}

// ======================================================================
// AVX2 + FMA (x86_64)
// ======================================================================

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::{
        __m256d, _mm256_castpd256_pd128, _mm256_extractf128_pd, _mm256_fmadd_pd,
        _mm256_loadu_pd, _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd, _mm_add_pd,
        _mm_cvtsd_f64, _mm_unpackhi_pd,
    };

    use super::{gemm4_packed_tail, gemm4_tail};

    /// Sum the four lanes of `v` as `(l0 + l2) + (l1 + l3)` — the same
    /// grouping as the portable lanes.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(v); // (l0, l1)
        let hi = _mm256_extractf128_pd::<1>(v); // (l2, l3)
        let s = _mm_add_pd(lo, hi); // (l0+l2, l1+l3)
        _mm_cvtsd_f64(s) + _mm_cvtsd_f64(_mm_unpackhi_pd(s, s))
    }

    /// `y[i] += a * x[i]`, 4 lanes at a time with FMA.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
        let n = y.len().min(x.len());
        let av = _mm256_set1_pd(a);
        let yp = y.as_mut_ptr();
        let xp = x.as_ptr();
        let quads = n / 4;
        for q in 0..quads {
            let i = 4 * q;
            let yv = _mm256_loadu_pd(yp.add(i));
            let xv = _mm256_loadu_pd(xp.add(i));
            _mm256_storeu_pd(yp.add(i), _mm256_fmadd_pd(av, xv, yv));
        }
        for i in 4 * quads..n {
            *yp.add(i) += a * *xp.add(i);
        }
    }

    /// FMA dot product with one 4-lane accumulator.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and FMA.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let quads = n / 4;
        let mut acc = _mm256_setzero_pd();
        for q in 0..quads {
            let i = 4 * q;
            acc = _mm256_fmadd_pd(_mm256_loadu_pd(ap.add(i)), _mm256_loadu_pd(bp.add(i)), acc);
        }
        let mut s = hsum(acc);
        for i in 4 * quads..n {
            s += *ap.add(i) * *bp.add(i);
        }
        s
    }

    /// 4x4 register tile: four `__m256d` accumulators (one per output
    /// row) held across the whole k panel, one broadcast + FMA per row
    /// per `b` vector load.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and FMA, `c.len() >= 4n`,
    /// `b.len() >= kend * n`, and every `a` row has at least `kend`
    /// entries (validated by [`super::Kernels::gemm4`]).
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm4(
        c: &mut [f64],
        n: usize,
        a: [&[f64]; 4],
        b: &[f64],
        kk: usize,
        kend: usize,
    ) {
        let [a0, a1, a2, a3] = a;
        let cp = c.as_mut_ptr();
        let bp = b.as_ptr();
        let quads = n / 4;
        for q in 0..quads {
            let j = 4 * q;
            let mut v0 = _mm256_loadu_pd(cp.add(j));
            let mut v1 = _mm256_loadu_pd(cp.add(n + j));
            let mut v2 = _mm256_loadu_pd(cp.add(2 * n + j));
            let mut v3 = _mm256_loadu_pd(cp.add(3 * n + j));
            for dk in kk..kend {
                let bv = _mm256_loadu_pd(bp.add(dk * n + j));
                v0 = _mm256_fmadd_pd(_mm256_set1_pd(*a0.get_unchecked(dk)), bv, v0);
                v1 = _mm256_fmadd_pd(_mm256_set1_pd(*a1.get_unchecked(dk)), bv, v1);
                v2 = _mm256_fmadd_pd(_mm256_set1_pd(*a2.get_unchecked(dk)), bv, v2);
                v3 = _mm256_fmadd_pd(_mm256_set1_pd(*a3.get_unchecked(dk)), bv, v3);
            }
            _mm256_storeu_pd(cp.add(j), v0);
            _mm256_storeu_pd(cp.add(n + j), v1);
            _mm256_storeu_pd(cp.add(2 * n + j), v2);
            _mm256_storeu_pd(cp.add(3 * n + j), v3);
        }
        gemm4_tail(c, n, [a0, a1, a2, a3], b, kk, kend, 4 * quads);
    }

    /// Packed-panel 4x4 register tile: the same FMA sequence as
    /// [`gemm4`] (bitwise), reading the NR=4 micro-panel layout so
    /// every `b` load is unit-stride.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 and FMA; geometry is
    /// validated by [`super::Kernels::gemm4_packed`].
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm4_packed(
        c: &mut [f64],
        n: usize,
        a: [&[f64]; 4],
        packed: &[f64],
        kk: usize,
        kend: usize,
    ) {
        let [a0, a1, a2, a3] = a;
        let cp = c.as_mut_ptr();
        let pp = packed.as_ptr();
        let kdepth = kend - kk;
        let quads = n / 4;
        for q in 0..quads {
            let j = 4 * q;
            let base = q * kdepth * 4;
            let mut v0 = _mm256_loadu_pd(cp.add(j));
            let mut v1 = _mm256_loadu_pd(cp.add(n + j));
            let mut v2 = _mm256_loadu_pd(cp.add(2 * n + j));
            let mut v3 = _mm256_loadu_pd(cp.add(3 * n + j));
            for d in 0..kdepth {
                let bv = _mm256_loadu_pd(pp.add(base + d * 4));
                let dk = kk + d;
                v0 = _mm256_fmadd_pd(_mm256_set1_pd(*a0.get_unchecked(dk)), bv, v0);
                v1 = _mm256_fmadd_pd(_mm256_set1_pd(*a1.get_unchecked(dk)), bv, v1);
                v2 = _mm256_fmadd_pd(_mm256_set1_pd(*a2.get_unchecked(dk)), bv, v2);
                v3 = _mm256_fmadd_pd(_mm256_set1_pd(*a3.get_unchecked(dk)), bv, v3);
            }
            _mm256_storeu_pd(cp.add(j), v0);
            _mm256_storeu_pd(cp.add(n + j), v1);
            _mm256_storeu_pd(cp.add(2 * n + j), v2);
            _mm256_storeu_pd(cp.add(3 * n + j), v3);
        }
        if 4 * quads < n {
            let tail = [&a0[kk..kend], &a1[kk..kend], &a2[kk..kend], &a3[kk..kend]];
            gemm4_packed_tail(c, n, tail, &packed[quads * kdepth * 4..], 4, 4 * quads);
        }
    }
}

// ======================================================================
// AVX-512F (x86_64) — f64x8 vectors, 4x8 register tile
// ======================================================================

#[cfg(target_arch = "x86_64")]
mod avx512 {
    use core::arch::x86_64::{_mm512_fmadd_pd, _mm512_loadu_pd, _mm512_set1_pd, _mm512_storeu_pd};

    use super::{gemm4_packed_tail, gemm4_tail};

    /// 4x8 register tile: four `__m512d` accumulators (one per output
    /// row) held across the whole k panel.  Per output element this is
    /// still one FMA per `dk`, `dk` ascending — the lane width only
    /// changes which *columns* share a vector, never the per-element
    /// operation sequence, so vector-covered columns match the AVX2
    /// tier bitwise.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX-512F; geometry is
    /// validated by [`super::Kernels::gemm4`].
    #[target_feature(enable = "avx512f")]
    pub unsafe fn gemm4(
        c: &mut [f64],
        n: usize,
        a: [&[f64]; 4],
        b: &[f64],
        kk: usize,
        kend: usize,
    ) {
        let [a0, a1, a2, a3] = a;
        let cp = c.as_mut_ptr();
        let bp = b.as_ptr();
        let octs = n / 8;
        for o in 0..octs {
            let j = 8 * o;
            let mut v0 = _mm512_loadu_pd(cp.add(j));
            let mut v1 = _mm512_loadu_pd(cp.add(n + j));
            let mut v2 = _mm512_loadu_pd(cp.add(2 * n + j));
            let mut v3 = _mm512_loadu_pd(cp.add(3 * n + j));
            for dk in kk..kend {
                let bv = _mm512_loadu_pd(bp.add(dk * n + j));
                v0 = _mm512_fmadd_pd(_mm512_set1_pd(*a0.get_unchecked(dk)), bv, v0);
                v1 = _mm512_fmadd_pd(_mm512_set1_pd(*a1.get_unchecked(dk)), bv, v1);
                v2 = _mm512_fmadd_pd(_mm512_set1_pd(*a2.get_unchecked(dk)), bv, v2);
                v3 = _mm512_fmadd_pd(_mm512_set1_pd(*a3.get_unchecked(dk)), bv, v3);
            }
            _mm512_storeu_pd(cp.add(j), v0);
            _mm512_storeu_pd(cp.add(n + j), v1);
            _mm512_storeu_pd(cp.add(2 * n + j), v2);
            _mm512_storeu_pd(cp.add(3 * n + j), v3);
        }
        gemm4_tail(c, n, [a0, a1, a2, a3], b, kk, kend, 8 * octs);
    }

    /// Packed-panel 4x8 register tile: the same FMA sequence as
    /// [`gemm4`] (bitwise), reading the NR=8 micro-panel layout so
    /// every `b` load is unit-stride.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX-512F; geometry is
    /// validated by [`super::Kernels::gemm4_packed`].
    #[target_feature(enable = "avx512f")]
    pub unsafe fn gemm4_packed(
        c: &mut [f64],
        n: usize,
        a: [&[f64]; 4],
        packed: &[f64],
        kk: usize,
        kend: usize,
    ) {
        let [a0, a1, a2, a3] = a;
        let cp = c.as_mut_ptr();
        let pp = packed.as_ptr();
        let kdepth = kend - kk;
        let octs = n / 8;
        for o in 0..octs {
            let j = 8 * o;
            let base = o * kdepth * 8;
            let mut v0 = _mm512_loadu_pd(cp.add(j));
            let mut v1 = _mm512_loadu_pd(cp.add(n + j));
            let mut v2 = _mm512_loadu_pd(cp.add(2 * n + j));
            let mut v3 = _mm512_loadu_pd(cp.add(3 * n + j));
            for d in 0..kdepth {
                let bv = _mm512_loadu_pd(pp.add(base + d * 8));
                let dk = kk + d;
                v0 = _mm512_fmadd_pd(_mm512_set1_pd(*a0.get_unchecked(dk)), bv, v0);
                v1 = _mm512_fmadd_pd(_mm512_set1_pd(*a1.get_unchecked(dk)), bv, v1);
                v2 = _mm512_fmadd_pd(_mm512_set1_pd(*a2.get_unchecked(dk)), bv, v2);
                v3 = _mm512_fmadd_pd(_mm512_set1_pd(*a3.get_unchecked(dk)), bv, v3);
            }
            _mm512_storeu_pd(cp.add(j), v0);
            _mm512_storeu_pd(cp.add(n + j), v1);
            _mm512_storeu_pd(cp.add(2 * n + j), v2);
            _mm512_storeu_pd(cp.add(3 * n + j), v3);
        }
        if 8 * octs < n {
            let tail = [&a0[kk..kend], &a1[kk..kend], &a2[kk..kend], &a3[kk..kend]];
            gemm4_packed_tail(c, n, tail, &packed[octs * kdepth * 8..], 8, 8 * octs);
        }
    }
}

// ======================================================================
// NEON (aarch64) — f64x2 pairs, kept 4-wide with two vectors per step
// ======================================================================

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::{
        vaddq_f64, vdupq_n_f64, vfmaq_f64, vgetq_lane_f64, vld1q_f64, vst1q_f64,
    };

    use super::{gemm4_packed_tail, gemm4_tail};

    /// `y[i] += a * x[i]`, two `f64x2` FMAs per step.
    ///
    /// # Safety
    /// NEON is baseline on aarch64; caller must still treat this as an
    /// intrinsic path (raw-pointer loops bounded by the slice lengths).
    pub unsafe fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
        let n = y.len().min(x.len());
        let av = vdupq_n_f64(a);
        let yp = y.as_mut_ptr();
        let xp = x.as_ptr();
        let quads = n / 4;
        for q in 0..quads {
            let i = 4 * q;
            let y0 = vld1q_f64(yp.add(i));
            let y1 = vld1q_f64(yp.add(i + 2));
            let x0 = vld1q_f64(xp.add(i));
            let x1 = vld1q_f64(xp.add(i + 2));
            vst1q_f64(yp.add(i), vfmaq_f64(y0, av, x0));
            vst1q_f64(yp.add(i + 2), vfmaq_f64(y1, av, x1));
        }
        for i in 4 * quads..n {
            *yp.add(i) += a * *xp.add(i);
        }
    }

    /// FMA dot with two `f64x2` accumulators holding lanes (l0, l1) and
    /// (l2, l3); summed `(l0+l2) + (l1+l3)` like the other paths.
    ///
    /// # Safety
    /// See [`axpy`].
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len().min(b.len());
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let quads = n / 4;
        let mut acc_lo = vdupq_n_f64(0.0);
        let mut acc_hi = vdupq_n_f64(0.0);
        for q in 0..quads {
            let i = 4 * q;
            acc_lo = vfmaq_f64(acc_lo, vld1q_f64(ap.add(i)), vld1q_f64(bp.add(i)));
            acc_hi = vfmaq_f64(acc_hi, vld1q_f64(ap.add(i + 2)), vld1q_f64(bp.add(i + 2)));
        }
        let pair = vaddq_f64(acc_lo, acc_hi); // (l0+l2, l1+l3)
        let mut s = vgetq_lane_f64::<0>(pair) + vgetq_lane_f64::<1>(pair);
        for i in 4 * quads..n {
            s += *ap.add(i) * *bp.add(i);
        }
        s
    }

    /// 4x4 register tile: eight `f64x2` accumulators (two per output
    /// row) held across the k panel.
    ///
    /// # Safety
    /// See [`axpy`]; geometry validated by [`super::Kernels::gemm4`].
    pub unsafe fn gemm4(
        c: &mut [f64],
        n: usize,
        a: [&[f64]; 4],
        b: &[f64],
        kk: usize,
        kend: usize,
    ) {
        let [a0, a1, a2, a3] = a;
        let cp = c.as_mut_ptr();
        let bp = b.as_ptr();
        let quads = n / 4;
        for q in 0..quads {
            let j = 4 * q;
            let mut v00 = vld1q_f64(cp.add(j));
            let mut v01 = vld1q_f64(cp.add(j + 2));
            let mut v10 = vld1q_f64(cp.add(n + j));
            let mut v11 = vld1q_f64(cp.add(n + j + 2));
            let mut v20 = vld1q_f64(cp.add(2 * n + j));
            let mut v21 = vld1q_f64(cp.add(2 * n + j + 2));
            let mut v30 = vld1q_f64(cp.add(3 * n + j));
            let mut v31 = vld1q_f64(cp.add(3 * n + j + 2));
            for dk in kk..kend {
                let b0 = vld1q_f64(bp.add(dk * n + j));
                let b1 = vld1q_f64(bp.add(dk * n + j + 2));
                let x0 = vdupq_n_f64(*a0.get_unchecked(dk));
                let x1 = vdupq_n_f64(*a1.get_unchecked(dk));
                let x2 = vdupq_n_f64(*a2.get_unchecked(dk));
                let x3 = vdupq_n_f64(*a3.get_unchecked(dk));
                v00 = vfmaq_f64(v00, x0, b0);
                v01 = vfmaq_f64(v01, x0, b1);
                v10 = vfmaq_f64(v10, x1, b0);
                v11 = vfmaq_f64(v11, x1, b1);
                v20 = vfmaq_f64(v20, x2, b0);
                v21 = vfmaq_f64(v21, x2, b1);
                v30 = vfmaq_f64(v30, x3, b0);
                v31 = vfmaq_f64(v31, x3, b1);
            }
            vst1q_f64(cp.add(j), v00);
            vst1q_f64(cp.add(j + 2), v01);
            vst1q_f64(cp.add(n + j), v10);
            vst1q_f64(cp.add(n + j + 2), v11);
            vst1q_f64(cp.add(2 * n + j), v20);
            vst1q_f64(cp.add(2 * n + j + 2), v21);
            vst1q_f64(cp.add(3 * n + j), v30);
            vst1q_f64(cp.add(3 * n + j + 2), v31);
        }
        gemm4_tail(c, n, [a0, a1, a2, a3], b, kk, kend, 4 * quads);
    }

    /// Packed-panel 4x4 register tile: the same FMA sequence as
    /// [`gemm4`] (bitwise), reading the NR=4 micro-panel layout so
    /// every `b` load is unit-stride.
    ///
    /// # Safety
    /// See [`axpy`]; geometry validated by
    /// [`super::Kernels::gemm4_packed`].
    pub unsafe fn gemm4_packed(
        c: &mut [f64],
        n: usize,
        a: [&[f64]; 4],
        packed: &[f64],
        kk: usize,
        kend: usize,
    ) {
        let [a0, a1, a2, a3] = a;
        let cp = c.as_mut_ptr();
        let pp = packed.as_ptr();
        let kdepth = kend - kk;
        let quads = n / 4;
        for q in 0..quads {
            let j = 4 * q;
            let base = q * kdepth * 4;
            let mut v00 = vld1q_f64(cp.add(j));
            let mut v01 = vld1q_f64(cp.add(j + 2));
            let mut v10 = vld1q_f64(cp.add(n + j));
            let mut v11 = vld1q_f64(cp.add(n + j + 2));
            let mut v20 = vld1q_f64(cp.add(2 * n + j));
            let mut v21 = vld1q_f64(cp.add(2 * n + j + 2));
            let mut v30 = vld1q_f64(cp.add(3 * n + j));
            let mut v31 = vld1q_f64(cp.add(3 * n + j + 2));
            for d in 0..kdepth {
                let b0 = vld1q_f64(pp.add(base + d * 4));
                let b1 = vld1q_f64(pp.add(base + d * 4 + 2));
                let dk = kk + d;
                let x0 = vdupq_n_f64(*a0.get_unchecked(dk));
                let x1 = vdupq_n_f64(*a1.get_unchecked(dk));
                let x2 = vdupq_n_f64(*a2.get_unchecked(dk));
                let x3 = vdupq_n_f64(*a3.get_unchecked(dk));
                v00 = vfmaq_f64(v00, x0, b0);
                v01 = vfmaq_f64(v01, x0, b1);
                v10 = vfmaq_f64(v10, x1, b0);
                v11 = vfmaq_f64(v11, x1, b1);
                v20 = vfmaq_f64(v20, x2, b0);
                v21 = vfmaq_f64(v21, x2, b1);
                v30 = vfmaq_f64(v30, x3, b0);
                v31 = vfmaq_f64(v31, x3, b1);
            }
            vst1q_f64(cp.add(j), v00);
            vst1q_f64(cp.add(j + 2), v01);
            vst1q_f64(cp.add(n + j), v10);
            vst1q_f64(cp.add(n + j + 2), v11);
            vst1q_f64(cp.add(2 * n + j), v20);
            vst1q_f64(cp.add(2 * n + j + 2), v21);
            vst1q_f64(cp.add(3 * n + j), v30);
            vst1q_f64(cp.add(3 * n + j + 2), v31);
        }
        if 4 * quads < n {
            let tail = [&a0[kk..kend], &a1[kk..kend], &a2[kk..kend], &a3[kk..kend]];
            gemm4_packed_tail(c, n, tail, &packed[quads * kdepth * 4..], 4, 4 * quads);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro;

    fn randv(n: usize, rng: &mut Xoshiro) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn detect_is_consistent() {
        let k = Kernels::detect();
        assert_eq!(k.isa(), Kernels::detect().isa(), "detection must be stable");
        assert_eq!(Kernels::portable().isa(), Isa::Portable);
        assert!(!k.isa().as_str().is_empty());
    }

    #[test]
    fn detected_kernels_match_portable_lanes() {
        // On AVX2/NEON machines this holds the intrinsic paths to the
        // portable lanes (difference is FMA's single rounding); elsewhere
        // it is trivially exact.
        let det = Kernels::detect();
        let port = Kernels::portable();
        let mut rng = Xoshiro::seeded(11);
        for n in [0usize, 1, 3, 4, 5, 8, 17, 64, 257, 1023] {
            let x = randv(n, &mut rng);
            let mut y1 = randv(n, &mut rng);
            let mut y2 = y1.clone();
            det.axpy(&mut y1, 1.3, &x);
            port.axpy(&mut y2, 1.3, &x);
            for (a, b) in y1.iter().zip(&y2) {
                close(*a, *b, 1e-12);
            }
            let b = randv(n, &mut rng);
            close(det.dot(&x, &b), port.dot(&x, &b), 1e-11 * (n as f64 + 1.0));
        }
    }

    #[test]
    fn gemm4_matches_reference_loops() {
        let det = Kernels::detect();
        let port = Kernels::portable();
        let mut rng = Xoshiro::seeded(23);
        // n exercises full vector blocks and 1/2/3-column tails
        for (n, kdim) in [(1usize, 3usize), (4, 7), (6, 1), (7, 19), (12, 33), (19, 257)] {
            let rows: Vec<Vec<f64>> = (0..4).map(|_| randv(kdim, &mut rng)).collect();
            let a = [&rows[0][..], &rows[1][..], &rows[2][..], &rows[3][..]];
            let b = randv(kdim * n, &mut rng);
            let c0 = randv(4 * n, &mut rng);
            // reference: plain triple loop, dk ascending per element
            let mut want = c0.clone();
            for (r, arow) in rows.iter().enumerate() {
                for j in 0..n {
                    let mut s = want[r * n + j];
                    for (dk, &x) in arow.iter().enumerate() {
                        s += x * b[dk * n + j];
                    }
                    want[r * n + j] = s;
                }
            }
            for k in [det, port] {
                let mut c = c0.clone();
                k.gemm4(&mut c, n, a, &b, 0, kdim);
                for (got, want) in c.iter().zip(&want) {
                    close(*got, *want, 1e-11 * (kdim as f64 + 1.0));
                }
            }
            // split k range: two panel calls must equal one
            let mut c_one = c0.clone();
            det.gemm4(&mut c_one, n, a, &b, 0, kdim);
            let mut c_two = c0.clone();
            let mid = kdim / 2;
            det.gemm4(&mut c_two, n, a, &b, 0, mid);
            det.gemm4(&mut c_two, n, a, &b, mid, kdim);
            assert_eq!(c_one, c_two, "panel split must not change results");
        }
    }

    #[test]
    #[should_panic(expected = "gemm4: b too short")]
    fn gemm4_validates_geometry() {
        let k = Kernels::portable();
        let row = [1.0, 2.0];
        let mut c = vec![0.0; 8];
        let b = vec![0.0; 3]; // needs kend * n = 2 * 2 = 4
        k.gemm4(&mut c, 2, [&row, &row, &row, &row], &b, 0, 2);
    }

    #[test]
    fn nr_matches_isa() {
        assert_eq!(Kernels::portable().nr(), 4);
        let det = Kernels::detect();
        let want = if det.isa() == Isa::Avx512 { 8 } else { 4 };
        assert_eq!(det.nr(), want);
    }

    #[test]
    fn env_override_forces_portable_lanes() {
        // Trivially passes when NDPP_SIMD_ISA is unset; on the CI
        // forced-portable matrix leg it pins the override end to end.
        if std::env::var("NDPP_SIMD_ISA").as_deref() == Ok("portable") {
            assert_eq!(Kernels::detect().isa(), Isa::Portable);
        }
    }

    #[test]
    fn pack_b_layout_roundtrip() {
        // Every panel entry lands where gemm4_packed expects it, and the
        // final block is zero-padded past column n — for both the
        // detected NR and the portable NR=4.
        let mut rng = Xoshiro::seeded(31);
        for k in [Kernels::detect(), Kernels::portable()] {
            let nr = k.nr();
            for (n, kdim) in [(1usize, 3usize), (4, 7), (7, 5), (8, 3), (9, 2), (12, 6), (17, 9)] {
                let b = randv(kdim * n, &mut rng);
                for (kk, kend) in [(0, kdim), (1, kdim), (0, 1), (kdim / 2, kdim)] {
                    let mut buf = Vec::new();
                    k.pack_b(&mut buf, &b, n, kk, kend);
                    let kdepth = kend - kk;
                    assert_eq!(buf.len(), n.div_ceil(nr) * nr * kdepth);
                    for jb in 0..n.div_ceil(nr) {
                        for d in 0..kdepth {
                            for l in 0..nr {
                                let col = jb * nr + l;
                                let got = buf[(jb * kdepth + d) * nr + l];
                                let want = if col < n { b[(kk + d) * n + col] } else { 0.0 };
                                assert_eq!(got, want, "block {jb} row {d} lane {l}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn gemm4_packed_is_bitwise_identical_to_gemm4() {
        // The packed walk re-orders memory, never arithmetic: per ISA it
        // must reproduce the unpacked kernel bit for bit, including
        // NR-straddling widths, partial tail blocks, and k=1 panels.
        let mut rng = Xoshiro::seeded(41);
        let shapes = [
            (1usize, 3usize),
            (4, 7),
            (5, 2),
            (7, 19),
            (8, 5),
            (9, 4),
            (12, 33),
            (16, 8),
            (19, 64),
        ];
        for k in [Kernels::detect(), Kernels::portable()] {
            for (n, kdim) in shapes {
                let rows: Vec<Vec<f64>> = (0..4).map(|_| randv(kdim, &mut rng)).collect();
                let a = [&rows[0][..], &rows[1][..], &rows[2][..], &rows[3][..]];
                let b = randv(kdim * n, &mut rng);
                let c0 = randv(4 * n, &mut rng);
                for (kk, kend) in [(0, kdim), (0, 1), (kdim / 2, kdim)] {
                    let mut unpacked = c0.clone();
                    k.gemm4(&mut unpacked, n, a, &b, kk, kend);
                    let mut buf = Vec::new();
                    k.pack_b(&mut buf, &b, n, kk, kend);
                    let mut packed = c0.clone();
                    k.gemm4_packed(&mut packed, n, a, &buf, kk, kend);
                    assert_eq!(unpacked, packed, "packed walk must match unpacked bitwise");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "gemm4_packed: panel too short")]
    fn gemm4_packed_validates_geometry() {
        let k = Kernels::portable();
        let row = [1.0, 2.0];
        let mut c = vec![0.0; 8];
        let packed = vec![0.0; 3]; // needs div_ceil(2, 4) * 4 * 2 = 8
        k.gemm4_packed(&mut c, 2, [&row, &row, &row, &row], &packed, 0, 2);
    }
}
