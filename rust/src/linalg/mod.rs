//! Dense linear-algebra substrate (f64, row-major).
//!
//! Nothing like LAPACK/nalgebra is available offline, and the NDPP
//! algorithms need determinants, inverses, QR, symmetric eigendecomposition
//! and the Youla (real Schur of a skew-symmetric matrix) decomposition.
//! Sizes are modest — `2K x 2K` inner matrices with `K <= 128`, `k x k`
//! minors with `k <= ~100` — so clarity and numerical robustness beat
//! blocked performance here.  The `O(M K^2)` item-axis work is elsewhere
//! (tiled in [`crate::sampler`] / offloaded to XLA artifacts).
//!
//! Contents:
//! * [`Matrix`] — row-major dense matrix with the usual ops.
//! * [`lu`] — LU with partial pivoting: determinant, solve, inverse.
//! * [`qr`] — Householder QR: orthonormalization, least squares.
//! * [`eigen`] — cyclic Jacobi symmetric eigendecomposition.
//! * [`skew`] — Youla decomposition of skew-symmetric matrices (via Jacobi
//!   on `-S^2` + pairing), the engine behind the paper's Algorithm 4.
//! * [`chol`] — Cholesky factorization of SPD matrices.

pub mod chol;
pub mod eigen;
pub mod lu;
pub mod matrix;
pub mod qr;
pub mod skew;
pub mod tridiag;

pub use chol::cholesky;
pub use eigen::{jacobi_eigen, SymEigen};
pub use lu::Lu;
pub use matrix::Matrix;
pub use qr::{householder_qr, Qr};
pub use skew::{youla_of_skew, YoulaPair};
pub use tridiag::sym_eigen;
