//! Dense linear-algebra substrate (f64, row-major).
//!
//! Nothing like LAPACK/nalgebra is available offline, and the NDPP
//! algorithms need determinants, inverses, QR, symmetric eigendecomposition
//! and the Youla (real Schur of a skew-symmetric matrix) decomposition.
//! Factorizations stay at modest sizes (`2K x 2K` inner matrices with
//! `K <= 128`, `k x k` minors with `k <= ~100`), but the `O(M K^2)`
//! item-axis products that feed them — Gram matrices, panel products, tree
//! statistics — are real GEMMs, so they route through a pluggable
//! [`backend`]: [`backend::NaiveBackend`] (reference loops, correctness
//! oracle), [`backend::BlockedBackend`] (cache-blocked, multithreaded;
//! the default), or [`backend::SimdBackend`] (blocked structure with
//! packed micro-panels and the runtime-dispatched microkernels of
//! [`simd`]).  Select with `NDPP_BACKEND=naive|blocked|simd`,
//! [`backend::set_active`], or [`crate::coordinator::ServiceConfig`].
//! Threaded ops run on the persistent worker pool of [`pool`], sized by
//! [`backend::thread_budget`].
//!
//! Contents:
//! * [`Matrix`] — row-major dense matrix; its `matmul`/`matvec`/`rank1_sub`
//!   family delegates to the active backend.
//! * [`backend`] — the compute-backend trait, implementations, selection,
//!   and the process-wide thread budget.
//! * [`simd`] — runtime-dispatched microkernels (AVX-512 / AVX2 / NEON /
//!   portable) and panel packing under the `simd` backend.
//! * [`pool`] — lazily-initialized persistent compute pool behind
//!   [`backend::fan_out_rows`].
//! * [`lu`] — LU with partial pivoting: determinant, solve, inverse.
//! * [`qr`] — Householder QR: orthonormalization, least squares (panel
//!   updates through the backend).
//! * [`eigen`] — cyclic Jacobi symmetric eigendecomposition.
//! * [`skew`] — Youla decomposition of skew-symmetric matrices (via Jacobi
//!   on `-S^2` + pairing), the engine behind the paper's Algorithm 4.
//! * [`chol`] — Cholesky factorization of SPD matrices.

pub mod backend;
pub mod chol;
pub mod eigen;
pub mod lu;
pub mod matrix;
pub mod pool;
pub mod qr;
pub mod simd;
pub mod skew;
pub mod tridiag;

pub use backend::{Backend, BackendKind};
pub use chol::cholesky;
pub use eigen::{jacobi_eigen, SymEigen};
pub use lu::Lu;
pub use matrix::Matrix;
pub use qr::{householder_qr, Qr};
pub use skew::{youla_of_skew, YoulaPair};
pub use tridiag::sym_eigen;
