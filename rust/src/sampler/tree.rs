//! Tree-based elementary-DPP sampling (paper §4.2, Algorithm 3;
//! Gillenwater et al. 2019) with the paper's improved per-node cost and a
//! hybrid leaf layout.
//!
//! Every internal node covering an item range `A` stores the `R x R`
//! matrix `Sigma_A = sum_{j in A} z_j z_j^T` (R = rank of the spectral
//! kernel).  One item is drawn by descending from the root, branching left
//! with probability
//!
//! ```text
//!   p_l = <Q^Y, (Sigma_left)_E> / <Q^Y, (Sigma_A)_E>,
//! ```
//!
//! an `O(|E|^2)` inner product per node (the paper's Proposition 1
//! improvement over the `O(|E|^3)`-per-node formulation), for a total of
//! `O(k^3 log M + k^4)` per sample after `O(K)` component selection.
//!
//! **Hybrid leaves**: the recursion stops at buckets of `leaf_size` items;
//! inside a bucket items are scored directly from their feature rows
//! (`O(leaf_size · |E|^2)`).  This divides tree memory by `leaf_size`
//! (the paper's full tree needed 169.5 GB for M = 1e6, K = 100 — see
//! DESIGN.md §4) at a negligible latency cost, and is ablated in
//! `benches/ablation.rs`.

use crate::linalg::backend::Backend as _;
use crate::linalg::Matrix;
use crate::ndpp::proposal::SpectralDpp;
use crate::rng::Xoshiro;
use crate::sampler::elementary::{item_score, select_elementary_into, ElementaryScratch};

/// Tree layout parameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeConfig {
    /// Stop splitting below this many items per node (1 = the paper's full
    /// binary tree down to single items).
    pub leaf_size: usize,
}

impl Default for TreeConfig {
    fn default() -> TreeConfig {
        TreeConfig { leaf_size: 64 }
    }
}

struct Node {
    start: usize,
    end: usize,
    /// flattened `R x R` outer-product sum for this range
    sigma: Vec<f64>,
    /// child indices (usize::MAX when this is a bucket leaf)
    left: usize,
    right: usize,
}

const NONE: usize = usize::MAX;

/// Preprocessed sampling tree over the items of a spectral DPP.
pub struct SampleTree {
    spectral: SpectralDpp,
    nodes: Vec<Node>,
    root: usize,
    config: TreeConfig,
}

impl SampleTree {
    /// `ConstructTree` (Algorithm 3 lines 10-11): `O(M R^2)` work in the
    /// leaf sweep, `O((M / leaf_size) R^2)` for internal sums.
    pub fn build(spectral: &SpectralDpp, config: TreeConfig) -> SampleTree {
        let m = spectral.m();
        assert!(m > 0, "empty ground set");
        let leaf = config.leaf_size.max(1);
        let mut nodes: Vec<Node> = Vec::with_capacity(2 * m.div_ceil(leaf));
        let root = Self::branch(spectral, 0, m, leaf, &mut nodes);
        SampleTree { spectral: spectral.clone(), nodes, root, config }
    }

    fn branch(
        spectral: &SpectralDpp,
        start: usize,
        end: usize,
        leaf: usize,
        nodes: &mut Vec<Node>,
    ) -> usize {
        if end - start <= leaf {
            // bucket leaf: Sigma = sum of z_j z_j^T over the bucket — the
            // backend's row-range SYRK, flattened row-major
            let sigma = crate::linalg::backend::active()
                .syrk(&spectral.vecs, start, end)
                .data;
            nodes.push(Node { start, end, sigma, left: NONE, right: NONE });
            return nodes.len() - 1;
        }
        let mid = start + (end - start) / 2;
        let l = Self::branch(spectral, start, mid, leaf, nodes);
        let rgt = Self::branch(spectral, mid, end, leaf, nodes);
        let mut sigma = nodes[l].sigma.clone();
        for (s, &x) in sigma.iter_mut().zip(&nodes[rgt].sigma) {
            *s += x;
        }
        nodes.push(Node { start, end, sigma, left: l, right: rgt });
        nodes.len() - 1
    }

    pub fn m(&self) -> usize {
        self.spectral.m()
    }

    pub fn spectral(&self) -> &SpectralDpp {
        &self.spectral
    }

    pub fn config(&self) -> TreeConfig {
        self.config
    }

    /// Bytes held in node `Sigma` matrices (the Table 3 "tree memory" row).
    pub fn memory_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| n.sigma.len() * std::mem::size_of::<f64>())
            .sum()
    }

    /// `<Q, (Sigma_node)_E>` — the restricted inner product of Eq. (12).
    #[inline]
    fn sigma_inner(&self, node: usize, e: &[usize], q: &Matrix) -> f64 {
        let r = self.spectral.rank();
        let sigma = &self.nodes[node].sigma;
        let ke = e.len();
        let mut acc = 0.0;
        for a in 0..ke {
            let base = e[a] * r;
            let qrow = q.row(a);
            for b in 0..ke {
                acc += qrow[b] * sigma[base + e[b]];
            }
        }
        acc
    }

    /// `SampleItem` (Algorithm 3 lines 21-28): draw one item conditioned on
    /// the current selection (encoded in `Q`).  `q` and `scores` come from
    /// the caller's [`ElementaryScratch`], so a descent never allocates.
    fn sample_item(
        &self,
        e: &[usize],
        q: &Matrix,
        scores: &mut Vec<f64>,
        rng: &mut Xoshiro,
    ) -> usize {
        let mut node = self.root;
        loop {
            let n = &self.nodes[node];
            if n.left == NONE {
                // bucket: score items directly
                scores.clear();
                scores.extend(
                    (n.start..n.end).map(|j| item_score(&self.spectral.vecs, j, e, q).max(0.0)),
                );
                let total: f64 = scores.iter().sum();
                if total <= 0.0 {
                    // numerically-dead bucket (can only happen through
                    // rounding); fall back to uniform within the bucket
                    return n.start + rng.below(n.end - n.start);
                }
                return n.start + rng.weighted(scores);
            }
            let pl = self.sigma_inner(n.left, e, q).max(0.0);
            let pr = self.sigma_inner(n.right, e, q).max(0.0);
            let total = pl + pr;
            node = if total <= 0.0 {
                // degenerate: split uniformly
                if rng.uniform() < 0.5 { n.left } else { n.right }
            } else if rng.uniform() <= pl / total {
                n.left
            } else {
                n.right
            };
        }
    }

    /// `SampleDPP` (Algorithm 3 lines 12-20): draw a full subset from the
    /// spectral DPP — select the elementary component, then `|E|` tree
    /// descents with conditional-kernel updates between picks.
    ///
    /// Convenience wrapper that allocates a one-shot workspace; loops
    /// should hold an [`ElementaryScratch`] and call
    /// [`SampleTree::sample_dpp_with`] instead.
    pub fn sample_dpp(&self, rng: &mut Xoshiro) -> Vec<usize> {
        let mut scratch = ElementaryScratch::with_rank(self.spectral.rank());
        self.sample_dpp_with(&mut scratch, rng)
    }

    /// [`SampleTree::sample_dpp`] with a caller-owned workspace: after the
    /// scratch has warmed up, the only heap allocation per sample is the
    /// returned subset itself.
    pub fn sample_dpp_with(
        &self,
        scratch: &mut ElementaryScratch,
        rng: &mut Xoshiro,
    ) -> Vec<usize> {
        select_elementary_into(&self.spectral.lambda, &mut scratch.e, rng);
        // detach the component list so the scratch can be borrowed mutably
        // for the descents (restored below — the buffer keeps its capacity)
        let e = std::mem::take(&mut scratch.e);
        let y = self.sample_elementary_with(&e, scratch, rng);
        scratch.e = e;
        y
    }

    /// Draw exactly `|E|` items from the elementary DPP indexed by `e`.
    pub fn sample_elementary(&self, e: &[usize], rng: &mut Xoshiro) -> Vec<usize> {
        let mut scratch = ElementaryScratch::with_rank(self.spectral.rank());
        self.sample_elementary_with(e, &mut scratch, rng)
    }

    /// [`SampleTree::sample_elementary`] with a caller-owned workspace.
    /// The conditional projector `Q^Y` is maintained incrementally inside
    /// the scratch (see [`ElementaryScratch`]), so each pick costs one tree
    /// descent plus an `O(|E|^2)` downdate — no per-pick factorization, no
    /// per-pick allocation.
    pub fn sample_elementary_with(
        &self,
        e: &[usize],
        scratch: &mut ElementaryScratch,
        rng: &mut Xoshiro,
    ) -> Vec<usize> {
        let mut y: Vec<usize> = Vec::with_capacity(e.len());
        scratch.reset_q(e.len());
        for _ in 0..e.len() {
            let j = {
                let ElementaryScratch { q, scores, .. } = &mut *scratch;
                self.sample_item(e, q, scores, rng)
            };
            scratch.condition_on(self.spectral.vecs.row(j), e);
            y.push(j);
        }
        y.sort_unstable();
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::{probability, NdppKernel, Proposal};
    use crate::sampler::elementary::select_elementary;
    use crate::sampler::test_support::tv;
    use crate::util::prop;

    fn spectral_fixture(seed: u64, m: usize, k: usize) -> SpectralDpp {
        let mut rng = Xoshiro::seeded(seed);
        let kernel = NdppKernel::random_ondpp(m, k, &mut rng);
        Proposal::build(&kernel).spectral()
    }

    #[test]
    fn root_sigma_is_total_gram() {
        prop::check("tree_root_sigma", 10, |g| {
            let m = g.usize_in(10, 60);
            let s = spectral_fixture(g.seed, m.max(17), 4);
            let leaf = *g.choice(&[1usize, 4, 16]);
            let tree = SampleTree::build(&s, TreeConfig { leaf_size: leaf });
            let r = s.rank();
            let gram = s.vecs.t_matmul(&s.vecs);
            let root = &tree.nodes[tree.root];
            for a in 0..r {
                for b in 0..r {
                    assert!(
                        (root.sigma[a * r + b] - gram[(a, b)]).abs() < 1e-9,
                        "a={a} b={b}"
                    );
                }
            }
        });
    }

    #[test]
    fn distribution_matches_direct_elementary_sampler() {
        // tree vs enumerated proposal-DPP distribution on tiny M
        let mut rng = Xoshiro::seeded(41);
        let kernel = NdppKernel::random_ondpp(6, 2, &mut rng);
        let proposal = Proposal::build(&kernel);
        let s = proposal.spectral();
        let want = probability::enumerate_probs_dense(&proposal.dense_lhat());
        for leaf in [1usize, 2, 8] {
            let tree = SampleTree::build(&s, TreeConfig { leaf_size: leaf });
            let n = 30_000;
            let mut counts = vec![0.0; 1 << 6];
            for _ in 0..n {
                let y = tree.sample_dpp(&mut rng);
                let mut mask = 0usize;
                for i in y {
                    mask |= 1 << i;
                }
                counts[mask] += 1.0;
            }
            for c in &mut counts {
                *c /= n as f64;
            }
            let d = tv(&counts, &want);
            assert!(d < 0.035, "leaf={leaf} tv={d}");
        }
    }

    #[test]
    fn sample_sizes_match_selected_component() {
        let s = spectral_fixture(42, 50, 4);
        let tree = SampleTree::build(&s, TreeConfig::default());
        let mut rng = Xoshiro::seeded(5);
        for _ in 0..30 {
            let e = select_elementary(&s.lambda, &mut rng);
            let y = tree.sample_elementary(&e, &mut rng);
            assert_eq!(y.len(), e.len());
            let mut yy = y.clone();
            yy.dedup();
            assert_eq!(yy.len(), y.len(), "duplicate item sampled");
        }
    }

    #[test]
    fn memory_shrinks_with_leaf_size() {
        let s = spectral_fixture(43, 256, 4);
        let full = SampleTree::build(&s, TreeConfig { leaf_size: 1 });
        let hybrid = SampleTree::build(&s, TreeConfig { leaf_size: 64 });
        assert!(hybrid.memory_bytes() * 8 < full.memory_bytes());
    }

    #[test]
    fn deterministic_given_seed() {
        let s = spectral_fixture(44, 64, 4);
        let tree = SampleTree::build(&s, TreeConfig::default());
        let mut r1 = Xoshiro::seeded(9);
        let mut r2 = Xoshiro::seeded(9);
        for _ in 0..10 {
            assert_eq!(tree.sample_dpp(&mut r1), tree.sample_dpp(&mut r2));
        }
    }

    #[test]
    fn scratch_reuse_matches_one_shot_path() {
        // a long-lived worker scratch must leak nothing between samples
        let s = spectral_fixture(46, 48, 4);
        let tree = SampleTree::build(&s, TreeConfig { leaf_size: 4 });
        let mut shared = ElementaryScratch::with_rank(s.rank());
        let mut r1 = Xoshiro::seeded(21);
        let mut r2 = Xoshiro::seeded(21);
        for _ in 0..20 {
            assert_eq!(
                tree.sample_dpp_with(&mut shared, &mut r1),
                tree.sample_dpp(&mut r2)
            );
        }
    }

    #[test]
    fn handles_m_not_power_of_two() {
        let s = spectral_fixture(45, 37, 2);
        let tree = SampleTree::build(&s, TreeConfig { leaf_size: 4 });
        let mut rng = Xoshiro::seeded(3);
        for _ in 0..50 {
            for j in tree.sample_dpp(&mut rng) {
                assert!(j < 37);
            }
        }
    }
}
