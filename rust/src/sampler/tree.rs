//! Tree-based elementary-DPP sampling (paper §4.2, Algorithm 3;
//! Gillenwater et al. 2019) with the paper's improved per-node cost and a
//! hybrid leaf layout.
//!
//! Every internal node covering an item range `A` stores the `R x R`
//! matrix `Sigma_A = sum_{j in A} z_j z_j^T` (R = rank of the spectral
//! kernel).  One item is drawn by descending from the root, branching left
//! with probability
//!
//! ```text
//!   p_l = <Q^Y, (Sigma_left)_E> / <Q^Y, (Sigma_A)_E>,
//! ```
//!
//! an `O(|E|^2)` inner product per node (the paper's Proposition 1
//! improvement over the `O(|E|^3)`-per-node formulation), for a total of
//! `O(k^3 log M + k^4)` per sample after `O(K)` component selection.
//!
//! **Hybrid leaves**: the recursion stops at buckets of `leaf_size` items;
//! inside a bucket items are scored directly from their feature rows
//! (`O(leaf_size · |E|^2)`).  This divides tree memory by `leaf_size`
//! (the paper's full tree needed 169.5 GB for M = 1e6, K = 100 — see
//! DESIGN.md §4) at a negligible latency cost, and is ablated in
//! `benches/ablation.rs`.

use crate::linalg::backend::{self, Backend as _};
use crate::linalg::Matrix;
use crate::ndpp::proposal::SpectralDpp;
use crate::rng::Xoshiro;
use crate::sampler::elementary::{item_score, select_elementary_into, ElementaryScratch};

thread_local! {
    /// Count of [`SampleTree::build`] calls on this thread — the
    /// observable half of the conditional subsystem's prep-free contract:
    /// conditional rejection sampling must reuse a prepared tree verbatim,
    /// so drawing any number of `given`-bearing samples leaves the calling
    /// thread's counter unchanged (asserted in `tests/conditional.rs`).
    /// Thread-local so concurrently running tests cannot race the
    /// assertion.
    static BUILD_COUNT: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of trees built *by the calling thread* so far.
pub fn build_count() -> u64 {
    BUILD_COUNT.with(|c| c.get())
}

/// Tree layout parameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeConfig {
    /// Stop splitting below this many items per node (1 = the paper's full
    /// binary tree down to single items).
    pub leaf_size: usize,
}

impl Default for TreeConfig {
    fn default() -> TreeConfig {
        TreeConfig { leaf_size: 64 }
    }
}

struct Node {
    start: usize,
    end: usize,
    /// flattened `R x R` outer-product sum for this range
    sigma: Vec<f64>,
    /// child indices (usize::MAX when this is a bucket leaf)
    left: usize,
    right: usize,
}

const NONE: usize = usize::MAX;

/// Preprocessed sampling tree over the items of a spectral DPP.
pub struct SampleTree {
    spectral: SpectralDpp,
    nodes: Vec<Node>,
    root: usize,
    config: TreeConfig,
}

impl SampleTree {
    /// `ConstructTree` (Algorithm 3 lines 10-11): `O(M R^2)` work in the
    /// leaf sweep, `O((M / leaf_size) R^2)` for internal sums.
    ///
    /// The leaf SYRKs are mutually independent, so they are fanned out
    /// across the backend's worker threads
    /// ([`backend::fan_out_rows`]) above a small work threshold;
    /// band boundaries are a pure function of shape and thread
    /// configuration and each leaf's statistic is the same backend SYRK
    /// call either way, so the tree is bit-identical to a serial build.
    pub fn build(spectral: &SpectralDpp, config: TreeConfig) -> SampleTree {
        let m = spectral.m();
        assert!(m > 0, "empty ground set");
        BUILD_COUNT.with(|c| c.set(c.get() + 1));
        let leaf = config.leaf_size.max(1);
        // leaf ranges first (same splits as the recursion, left-first)
        let mut leaves: Vec<(usize, usize)> = Vec::with_capacity(m.div_ceil(leaf));
        Self::collect_leaves(0, m, leaf, &mut leaves);
        let r = spectral.rank();
        let rr = r * r;
        let mut sigmas = vec![0.0f64; leaves.len() * rr];
        if rr > 0 {
            // total leaf work ~ 2 M R^2 flops; gate on the backend's own
            // fan-out floor so the tree and the GEMM kernels share one
            // tuned threshold
            let threads = if 2 * m * rr >= backend::PAR_MIN_FLOPS {
                backend::configured_threads()
            } else {
                1
            };
            let leaves_ref = &leaves;
            backend::fan_out_rows(&mut sigmas, rr, leaves.len(), threads, |chunk, l0, l1| {
                for (off, li) in (l0..l1).enumerate() {
                    let (s, e) = leaves_ref[li];
                    let sig = backend::active().syrk(&spectral.vecs, s, e);
                    chunk[off * rr..(off + 1) * rr].copy_from_slice(&sig.data);
                }
            });
        }
        let mut nodes: Vec<Node> = Vec::with_capacity(2 * leaves.len());
        let mut next_leaf = 0usize;
        let root = Self::branch(0, m, leaf, &mut nodes, &sigmas, rr, &mut next_leaf);
        debug_assert_eq!(next_leaf, leaves.len());
        SampleTree { spectral: spectral.clone(), nodes, root, config }
    }

    /// The leaf ranges of the recursion in DFS (left-first) order.
    fn collect_leaves(start: usize, end: usize, leaf: usize, out: &mut Vec<(usize, usize)>) {
        if end - start <= leaf {
            out.push((start, end));
            return;
        }
        let mid = start + (end - start) / 2;
        Self::collect_leaves(start, mid, leaf, out);
        Self::collect_leaves(mid, end, leaf, out);
    }

    fn branch(
        start: usize,
        end: usize,
        leaf: usize,
        nodes: &mut Vec<Node>,
        leaf_sigmas: &[f64],
        rr: usize,
        next_leaf: &mut usize,
    ) -> usize {
        if end - start <= leaf {
            // bucket leaf: Sigma = sum of z_j z_j^T over the bucket —
            // precomputed above (backend row-range SYRK, flattened
            // row-major), consumed in the same DFS order it was laid out
            let sigma = leaf_sigmas[*next_leaf * rr..(*next_leaf + 1) * rr].to_vec();
            *next_leaf += 1;
            nodes.push(Node { start, end, sigma, left: NONE, right: NONE });
            return nodes.len() - 1;
        }
        let mid = start + (end - start) / 2;
        let l = Self::branch(start, mid, leaf, nodes, leaf_sigmas, rr, next_leaf);
        let rgt = Self::branch(mid, end, leaf, nodes, leaf_sigmas, rr, next_leaf);
        let mut sigma = nodes[l].sigma.clone();
        for (s, &x) in sigma.iter_mut().zip(&nodes[rgt].sigma) {
            *s += x;
        }
        nodes.push(Node { start, end, sigma, left: l, right: rgt });
        nodes.len() - 1
    }

    pub fn m(&self) -> usize {
        self.spectral.m()
    }

    pub fn spectral(&self) -> &SpectralDpp {
        &self.spectral
    }

    pub fn config(&self) -> TreeConfig {
        self.config
    }

    /// Bytes held in node `Sigma` matrices (the Table 3 "tree memory" row).
    pub fn memory_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| n.sigma.len() * std::mem::size_of::<f64>())
            .sum()
    }

    /// `<Q, (Sigma_node)_E>` — the restricted inner product of Eq. (12).
    #[inline]
    fn sigma_inner(&self, node: usize, e: &[usize], q: &Matrix) -> f64 {
        let r = self.spectral.rank();
        let sigma = &self.nodes[node].sigma;
        let ke = e.len();
        let mut acc = 0.0;
        for a in 0..ke {
            let base = e[a] * r;
            let qrow = q.row(a);
            for b in 0..ke {
                acc += qrow[b] * sigma[base + e[b]];
            }
        }
        acc
    }

    /// `SampleItem` (Algorithm 3 lines 21-28): draw one item conditioned on
    /// the current selection (encoded in `Q`).  `q` and `scores` come from
    /// the caller's [`ElementaryScratch`], so a descent never allocates.
    fn sample_item(
        &self,
        e: &[usize],
        q: &Matrix,
        scores: &mut Vec<f64>,
        rng: &mut Xoshiro,
    ) -> usize {
        let mut node = self.root;
        loop {
            let n = &self.nodes[node];
            if n.left == NONE {
                // bucket: score items directly
                scores.clear();
                scores.extend(
                    (n.start..n.end).map(|j| item_score(&self.spectral.vecs, j, e, q).max(0.0)),
                );
                let total: f64 = scores.iter().sum();
                if total <= 0.0 {
                    // numerically-dead bucket (can only happen through
                    // rounding); fall back to uniform within the bucket
                    return n.start + rng.below(n.end - n.start);
                }
                return n.start + rng.weighted(scores);
            }
            let pl = self.sigma_inner(n.left, e, q).max(0.0);
            let pr = self.sigma_inner(n.right, e, q).max(0.0);
            let total = pl + pr;
            node = if total <= 0.0 {
                // degenerate: split uniformly
                if rng.uniform() < 0.5 { n.left } else { n.right }
            } else if rng.uniform() <= pl / total {
                n.left
            } else {
                n.right
            };
        }
    }

    /// `SampleDPP` (Algorithm 3 lines 12-20): draw a full subset from the
    /// spectral DPP — select the elementary component, then `|E|` tree
    /// descents with conditional-kernel updates between picks.
    ///
    /// Convenience wrapper that allocates a one-shot workspace; loops
    /// should hold an [`ElementaryScratch`] and call
    /// [`SampleTree::sample_dpp_with`] instead.
    pub fn sample_dpp(&self, rng: &mut Xoshiro) -> Vec<usize> {
        let mut scratch = ElementaryScratch::with_rank(self.spectral.rank());
        self.sample_dpp_with(&mut scratch, rng)
    }

    /// [`SampleTree::sample_dpp`] with a caller-owned workspace: after the
    /// scratch has warmed up, the only heap allocation per sample is the
    /// returned subset itself.
    pub fn sample_dpp_with(
        &self,
        scratch: &mut ElementaryScratch,
        rng: &mut Xoshiro,
    ) -> Vec<usize> {
        select_elementary_into(&self.spectral.lambda, &mut scratch.e, rng);
        // detach the component list so the scratch can be borrowed mutably
        // for the descents (restored below — the buffer keeps its capacity)
        let e = std::mem::take(&mut scratch.e);
        let y = self.sample_elementary_with(&e, scratch, rng);
        scratch.e = e;
        y
    }

    /// Draw exactly `|E|` items from the elementary DPP indexed by `e`.
    pub fn sample_elementary(&self, e: &[usize], rng: &mut Xoshiro) -> Vec<usize> {
        let mut scratch = ElementaryScratch::with_rank(self.spectral.rank());
        self.sample_elementary_with(e, &mut scratch, rng)
    }

    /// [`SampleTree::sample_elementary`] with a caller-owned workspace.
    /// The conditional projector `Q^Y` is maintained incrementally inside
    /// the scratch (see [`ElementaryScratch`]), so each pick costs one tree
    /// descent plus an `O(|E|^2)` downdate — no per-pick factorization, no
    /// per-pick allocation.
    pub fn sample_elementary_with(
        &self,
        e: &[usize],
        scratch: &mut ElementaryScratch,
        rng: &mut Xoshiro,
    ) -> Vec<usize> {
        let mut y: Vec<usize> = Vec::with_capacity(e.len());
        scratch.reset_q(e.len());
        for _ in 0..e.len() {
            let j = {
                let ElementaryScratch { q, scores, .. } = &mut *scratch;
                self.sample_item(e, q, scores, rng)
            };
            scratch.condition_on(self.spectral.vecs.row(j), e);
            y.push(j);
        }
        y.sort_unstable();
        y
    }

    // ---- projected (conditional) descent --------------------------------
    //
    // The conditional rejection sampler (`sampler::conditional`) samples a
    // *different* symmetric DPP over the same item features: the prepared
    // proposal conditioned/recombined per request.  Its elementary
    // components are eigenvectors of an `R x R` inner matrix, i.e. linear
    // combinations of the prepared eigenbasis — so instead of an
    // `|E| x |E|` projector over selected coordinates, the descent carries
    // a full-rank `R x R` projector `Q̃` (the selected subspace expressed
    // in the prepared basis).  Node probabilities become the *unrestricted*
    // inner products `<Q̃, Sigma_A>`, which reuse the prepared node
    // statistics verbatim: conditioning never touches the tree.

    /// `<Q̃, Sigma_node>` over the full `R x R` statistics.
    #[inline]
    fn sigma_inner_projected(&self, node: usize, q: &Matrix) -> f64 {
        let r = self.spectral.rank();
        let sigma = &self.nodes[node].sigma;
        let mut acc = 0.0;
        for a in 0..r {
            let qrow = q.row(a);
            let base = a * r;
            for b in 0..r {
                acc += qrow[b] * sigma[base + b];
            }
        }
        acc
    }

    /// Score of one item under the projector: `v_j^T Q̃ v_j`.
    #[inline]
    fn item_score_projected(&self, j: usize, q: &Matrix) -> f64 {
        let row = self.spectral.vecs.row(j);
        let r = row.len();
        let mut acc = 0.0;
        for a in 0..r {
            let va = row[a];
            if va == 0.0 {
                continue;
            }
            let qrow = q.row(a);
            let mut inner = 0.0;
            for b in 0..r {
                inner += qrow[b] * row[b];
            }
            acc += va * inner;
        }
        acc
    }

    /// One tree descent under a full-rank projector `Q̃`.  Items in
    /// `excluded` (sorted) carry exactly-zero mass under a conditioned
    /// projector; their scores are clamped to zero against floating-point
    /// leakage, and the measure-zero dead-bucket fallback never returns
    /// them.
    fn sample_item_projected(
        &self,
        q: &Matrix,
        scores: &mut Vec<f64>,
        excluded: &[usize],
        rng: &mut Xoshiro,
    ) -> usize {
        let mut node = self.root;
        loop {
            let n = &self.nodes[node];
            if n.left == NONE {
                scores.clear();
                scores.extend((n.start..n.end).map(|j| {
                    if excluded.binary_search(&j).is_ok() {
                        0.0
                    } else {
                        self.item_score_projected(j, q).max(0.0)
                    }
                }));
                let total: f64 = scores.iter().sum();
                if total > 0.0 {
                    return n.start + rng.weighted(scores);
                }
                // numerically-dead bucket (rounding only): uniform over the
                // bucket's admissible items, walking forward when the
                // bucket is entirely excluded
                let free: Vec<usize> = (n.start..n.end)
                    .filter(|j| excluded.binary_search(j).is_err())
                    .collect();
                if !free.is_empty() {
                    return free[rng.below(free.len())];
                }
                let m = self.m();
                let mut j = n.end % m;
                while excluded.binary_search(&j).is_ok() {
                    j = (j + 1) % m;
                }
                return j;
            }
            let pl = self.sigma_inner_projected(n.left, q).max(0.0);
            let pr = self.sigma_inner_projected(n.right, q).max(0.0);
            let total = pl + pr;
            node = if total <= 0.0 {
                if rng.uniform() < 0.5 { n.left } else { n.right }
            } else if rng.uniform() <= pl / total {
                n.left
            } else {
                n.right
            };
        }
    }

    // ---- tree-driven MCMC proposals -------------------------------------
    //
    // The up-down chain (`sampler::mcmc`) needs a proposal distribution
    // over single items that (a) concentrates on items the target gives
    // mass to, (b) is drawable in sublinear time, and (c) has *exactly*
    // computable point probabilities for the Hastings correction.  The
    // prepared node statistics give all three: under an `R x R` PSD weight
    // matrix `W` the descent below draws item `j` with probability
    // proportional to `v_j^T W v_j` (e.g. `W = diag(lambda/(1+lambda))`
    // makes that the proposal-DPP marginal `K̂_jj`), and because the
    // measure is defined *by the descent itself* — branch odds from
    // `<W, Sigma_child>`, leaf odds from the clamped item scores, with the
    // same deterministic fallbacks on numerically-dead mass — the exact
    // probability of any item is recoverable by a root-to-leaf walk.

    /// Leaf-bucket scores under `w` with `excluded` (sorted) clamped to
    /// zero; returns the bucket total.
    fn fill_bucket_scores(
        &self,
        node: &Node,
        w: &Matrix,
        excluded: &[usize],
        scores: &mut Vec<f64>,
    ) -> f64 {
        scores.clear();
        scores.extend((node.start..node.end).map(|j| {
            if excluded.binary_search(&j).is_ok() {
                0.0
            } else {
                self.item_score_projected(j, w).max(0.0)
            }
        }));
        scores.iter().sum()
    }

    /// One weighted descent for the MCMC up-move proposal: draws an item
    /// with probability proportional to `v_j^T W v_j` (items in `excluded`
    /// carry zero leaf mass) and returns `(item, probability)` where the
    /// probability is the **exact** mass the descent measure assigns to the
    /// returned item — the product of the branch odds along the path times
    /// the leaf odds, including the uniform fallbacks taken on
    /// numerically-dead nodes/buckets.  `O((log M + leaf_size) R^2)` per
    /// draw, zero allocation (`scores` is the caller's scratch).
    ///
    /// A dead bucket falls back to uniform over its *full* span, so the
    /// returned item may be excluded; Metropolis callers treat proposing an
    /// excluded/held item as a rejected self-loop, which keeps the point
    /// probabilities single-path and exact.
    pub fn propose_item_with(
        &self,
        w: &Matrix,
        scores: &mut Vec<f64>,
        excluded: &[usize],
        rng: &mut Xoshiro,
    ) -> (usize, f64) {
        let mut node = self.root;
        let mut prob = 1.0f64;
        loop {
            let n = &self.nodes[node];
            if n.left == NONE {
                let total = self.fill_bucket_scores(n, w, excluded, scores);
                if total > 0.0 {
                    let idx = rng.weighted(scores);
                    return (n.start + idx, prob * scores[idx] / total);
                }
                // numerically-dead bucket: uniform over the full span (the
                // walk in `proposal_prob` reproduces this measure exactly)
                let len = n.end - n.start;
                return (n.start + rng.below(len), prob / len as f64);
            }
            let pl = self.sigma_inner_projected(n.left, w).max(0.0);
            let pr = self.sigma_inner_projected(n.right, w).max(0.0);
            let total = pl + pr;
            if total <= 0.0 {
                prob *= 0.5;
                node = if rng.uniform() < 0.5 { n.left } else { n.right };
            } else {
                let frac = pl / total;
                if rng.uniform() <= frac {
                    prob *= frac;
                    node = n.left;
                } else {
                    prob *= 1.0 - frac;
                    node = n.right;
                }
            }
        }
    }

    /// The exact probability [`SampleTree::propose_item_with`] (same `w`,
    /// same `excluded`) assigns to item `j` — a deterministic root-to-leaf
    /// walk through the same branch odds, `O((log M + leaf_size) R^2)`.
    /// Zero for an excluded (or zero-score) item in a live bucket; nonzero
    /// for every item of a dead bucket.
    pub fn proposal_prob(
        &self,
        j: usize,
        w: &Matrix,
        scores: &mut Vec<f64>,
        excluded: &[usize],
    ) -> f64 {
        assert!(j < self.m(), "item {j} out of range (M = {})", self.m());
        let mut node = self.root;
        let mut prob = 1.0f64;
        loop {
            let n = &self.nodes[node];
            if n.left == NONE {
                let total = self.fill_bucket_scores(n, w, excluded, scores);
                return if total > 0.0 {
                    prob * scores[j - n.start] / total
                } else {
                    prob / (n.end - n.start) as f64
                };
            }
            let pl = self.sigma_inner_projected(n.left, w).max(0.0);
            let pr = self.sigma_inner_projected(n.right, w).max(0.0);
            let total = pl + pr;
            let go_left = j < self.nodes[n.left].end;
            if total <= 0.0 {
                prob *= 0.5;
            } else {
                let frac = pl / total;
                prob *= if go_left { frac } else { 1.0 - frac };
            }
            node = if go_left { n.left } else { n.right };
        }
    }

    /// Draw exactly `count` items from the elementary DPP whose selected
    /// subspace is encoded in the `R x R` projector `q` (initialized by
    /// the caller to `U_E U_E^T` for selected eigenvector columns `U_E` in
    /// the prepared basis).  After each pick with feature row `v`, `q` is
    /// downdated in place with the same Gram–Schmidt step as
    /// [`ElementaryScratch::condition_on`]:
    /// `Q̃ <- Q̃ − (Q̃ v)(Q̃ v)^T / (v^T Q̃ v)`.
    ///
    /// `qa` and `scores` are caller-owned buffers (no allocation here
    /// beyond the returned subset); `excluded` (sorted) is never sampled.
    pub fn sample_projected_with(
        &self,
        q: &mut Matrix,
        count: usize,
        excluded: &[usize],
        qa: &mut Vec<f64>,
        scores: &mut Vec<f64>,
        rng: &mut Xoshiro,
    ) -> Vec<usize> {
        let r = self.spectral.rank();
        debug_assert_eq!((q.rows, q.cols), (r, r));
        let mut y: Vec<usize> = Vec::with_capacity(count);
        for _ in 0..count {
            let j = self.sample_item_projected(q, scores, excluded, rng);
            // downdate: qa = Q̃ v_j (Q̃ symmetric), p = v_j^T qa
            let row = self.spectral.vecs.row(j);
            qa.clear();
            for a in 0..r {
                qa.push(crate::linalg::matrix::dot(q.row(a), row));
            }
            let p: f64 = crate::linalg::matrix::dot(row, qa);
            let inv = 1.0 / p.max(1e-300);
            for a in 0..r {
                let f = qa[a] * inv;
                if f == 0.0 {
                    continue;
                }
                let qrow = q.row_mut(a);
                for (qv, &qb) in qrow.iter_mut().zip(qa.iter()) {
                    *qv -= f * qb;
                }
            }
            y.push(j);
        }
        y.sort_unstable();
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::{probability, NdppKernel, Proposal};
    use crate::sampler::elementary::select_elementary;
    use crate::sampler::test_support::tv;
    use crate::util::prop;

    fn spectral_fixture(seed: u64, m: usize, k: usize) -> SpectralDpp {
        let mut rng = Xoshiro::seeded(seed);
        let kernel = NdppKernel::random_ondpp(m, k, &mut rng);
        Proposal::build(&kernel).spectral()
    }

    #[test]
    fn root_sigma_is_total_gram() {
        prop::check("tree_root_sigma", 10, |g| {
            let m = g.usize_in(10, 60);
            let s = spectral_fixture(g.seed, m.max(17), 4);
            let leaf = *g.choice(&[1usize, 4, 16]);
            let tree = SampleTree::build(&s, TreeConfig { leaf_size: leaf });
            let r = s.rank();
            let gram = s.vecs.t_matmul(&s.vecs);
            let root = &tree.nodes[tree.root];
            for a in 0..r {
                for b in 0..r {
                    assert!(
                        (root.sigma[a * r + b] - gram[(a, b)]).abs() < 1e-9,
                        "a={a} b={b}"
                    );
                }
            }
        });
    }

    #[test]
    fn distribution_matches_direct_elementary_sampler() {
        // tree vs enumerated proposal-DPP distribution on tiny M
        let mut rng = Xoshiro::seeded(41);
        let kernel = NdppKernel::random_ondpp(6, 2, &mut rng);
        let proposal = Proposal::build(&kernel);
        let s = proposal.spectral();
        let want = probability::enumerate_probs_dense(&proposal.dense_lhat());
        for leaf in [1usize, 2, 8] {
            let tree = SampleTree::build(&s, TreeConfig { leaf_size: leaf });
            let n = 30_000;
            let mut counts = vec![0.0; 1 << 6];
            for _ in 0..n {
                let y = tree.sample_dpp(&mut rng);
                let mut mask = 0usize;
                for i in y {
                    mask |= 1 << i;
                }
                counts[mask] += 1.0;
            }
            for c in &mut counts {
                *c /= n as f64;
            }
            let d = tv(&counts, &want);
            assert!(d < 0.035, "leaf={leaf} tv={d}");
        }
    }

    #[test]
    fn sample_sizes_match_selected_component() {
        let s = spectral_fixture(42, 50, 4);
        let tree = SampleTree::build(&s, TreeConfig::default());
        let mut rng = Xoshiro::seeded(5);
        for _ in 0..30 {
            let e = select_elementary(&s.lambda, &mut rng);
            let y = tree.sample_elementary(&e, &mut rng);
            assert_eq!(y.len(), e.len());
            let mut yy = y.clone();
            yy.dedup();
            assert_eq!(yy.len(), y.len(), "duplicate item sampled");
        }
    }

    #[test]
    fn memory_shrinks_with_leaf_size() {
        let s = spectral_fixture(43, 256, 4);
        let full = SampleTree::build(&s, TreeConfig { leaf_size: 1 });
        let hybrid = SampleTree::build(&s, TreeConfig { leaf_size: 64 });
        assert!(hybrid.memory_bytes() * 8 < full.memory_bytes());
    }

    #[test]
    fn deterministic_given_seed() {
        let s = spectral_fixture(44, 64, 4);
        let tree = SampleTree::build(&s, TreeConfig::default());
        let mut r1 = Xoshiro::seeded(9);
        let mut r2 = Xoshiro::seeded(9);
        for _ in 0..10 {
            assert_eq!(tree.sample_dpp(&mut r1), tree.sample_dpp(&mut r2));
        }
    }

    #[test]
    fn scratch_reuse_matches_one_shot_path() {
        // a long-lived worker scratch must leak nothing between samples
        let s = spectral_fixture(46, 48, 4);
        let tree = SampleTree::build(&s, TreeConfig { leaf_size: 4 });
        let mut shared = ElementaryScratch::with_rank(s.rank());
        let mut r1 = Xoshiro::seeded(21);
        let mut r2 = Xoshiro::seeded(21);
        for _ in 0..20 {
            assert_eq!(
                tree.sample_dpp_with(&mut shared, &mut r1),
                tree.sample_dpp(&mut r2)
            );
        }
    }

    /// Dense `diag(lambda / (1 + lambda))` — the proposal-marginal weight
    /// the MCMC tree proposal descends under.
    fn marginal_weight(s: &SpectralDpp) -> Matrix {
        let r = s.rank();
        let mut w = Matrix::zeros(r, r);
        for i in 0..r {
            w[(i, i)] = s.lambda[i] / (1.0 + s.lambda[i]);
        }
        w
    }

    #[test]
    fn proposal_prob_is_a_distribution_matching_item_weights() {
        prop::check("tree_proposal_prob", 8, |g| {
            let m = g.usize_in(9, 40);
            let s = spectral_fixture(g.seed, m.max(17), 2);
            let m = s.m();
            let leaf = *g.choice(&[1usize, 4, 16]);
            let tree = SampleTree::build(&s, TreeConfig { leaf_size: leaf });
            let w = marginal_weight(&s);
            let mut scores = Vec::new();
            for excluded in [vec![], vec![0, m / 2, m - 1]] {
                let probs: Vec<f64> =
                    (0..m).map(|j| tree.proposal_prob(j, &w, &mut scores, &excluded)).collect();
                let total: f64 = probs.iter().sum();
                assert!((total - 1.0).abs() < 1e-9, "leaf={leaf} total={total}");
                // live buckets: mass proportional to v_j^T W v_j, zero on
                // the excluded items
                let weights: Vec<f64> = (0..m)
                    .map(|j| {
                        if excluded.contains(&j) {
                            0.0
                        } else {
                            tree.item_score_projected(j, &w).max(0.0)
                        }
                    })
                    .collect();
                let wtotal: f64 = weights.iter().sum();
                for j in 0..m {
                    assert!(
                        (probs[j] - weights[j] / wtotal).abs() < 1e-9,
                        "leaf={leaf} j={j} got={} want={}",
                        probs[j],
                        weights[j] / wtotal
                    );
                }
            }
        });
    }

    #[test]
    fn propose_item_matches_walked_probability_and_frequency() {
        let s = spectral_fixture(47, 23, 2);
        let m = s.m();
        let tree = SampleTree::build(&s, TreeConfig { leaf_size: 4 });
        let w = marginal_weight(&s);
        let mut scores = Vec::new();
        let excluded = vec![2usize, 11];
        let mut rng = Xoshiro::seeded(13);
        let n = 60_000;
        let mut counts = vec![0.0f64; m];
        for _ in 0..n {
            let (j, p) = tree.propose_item_with(&w, &mut scores, &excluded, &mut rng);
            assert!(j < m);
            // the returned probability must be the walked probability
            let walked = tree.proposal_prob(j, &w, &mut scores, &excluded);
            assert!((p - walked).abs() < 1e-12 * (1.0 + walked), "j={j} p={p} walked={walked}");
            assert!(!excluded.contains(&j), "live buckets never propose excluded items");
            counts[j] += 1.0;
        }
        for c in &mut counts {
            *c /= n as f64;
        }
        let want: Vec<f64> =
            (0..m).map(|j| tree.proposal_prob(j, &w, &mut scores, &excluded)).collect();
        let d = tv(&counts, &want);
        assert!(d < 0.02, "tv={d}");
    }

    #[test]
    fn handles_m_not_power_of_two() {
        let s = spectral_fixture(45, 37, 2);
        let tree = SampleTree::build(&s, TreeConfig { leaf_size: 4 });
        let mut rng = Xoshiro::seeded(3);
        for _ in 0..50 {
            for j in tree.sample_dpp(&mut rng) {
                assert!(j < 37);
            }
        }
    }
}
