//! Elementary-DPP machinery — the mixture components of a spectral DPP
//! (paper Eq. (10), Kulesza & Taskar 2012 Lemma 2.6).
//!
//! Sampling a symmetric DPP with eigendecomposition `{(lambda_i, v_i)}`
//! is a two-step process:
//!
//! 1. select an eigenvector index set `E` by independent coin flips with
//!    `Pr(i in E) = lambda_i / (lambda_i + 1)` ([`select_elementary`]);
//! 2. sample exactly `|E|` items from the *elementary* DPP with marginal
//!    kernel `K^E = Z_{:,E} Z_{:,E}^T` ([`sample_elementary_direct`], or
//!    the tree-accelerated version in [`crate::sampler::tree`]).
//!
//! The direct version scans all M items per selection — `O(M k^2)` per
//! item, the baseline the tree beats (Proposition 1).

use crate::linalg::{lu::Lu, Matrix};
use crate::ndpp::proposal::SpectralDpp;
use crate::rng::Xoshiro;

/// Step 1: choose the elementary component by 2K coin flips.
pub fn select_elementary(lambda: &[f64], rng: &mut Xoshiro) -> Vec<usize> {
    let mut e = Vec::new();
    select_elementary_into(lambda, &mut e, rng);
    e
}

/// [`select_elementary`] into a caller-owned buffer (identical coin-flip
/// stream, zero allocation in steady state).
pub fn select_elementary_into(lambda: &[f64], out: &mut Vec<usize>, rng: &mut Xoshiro) {
    out.clear();
    for (i, &l) in lambda.iter().enumerate() {
        if rng.uniform() <= l / (l + 1.0) {
            out.push(i);
        }
    }
}

/// Reusable workspace for elementary-DPP sampling — the *Scratch* half of
/// the serving pipeline's Prepared/Scratch split (the immutable *Prepared*
/// half being the [`SpectralDpp`] / [`crate::sampler::SampleTree`] built
/// once per model).
///
/// Holds the conditional projector `Q^Y`, its downdate vector, the
/// per-bucket/per-item score buffer, and the selected-component index
/// list.  One scratch per worker thread serves any number of sequential
/// samples with zero heap allocation in the per-sample hot loop once the
/// buffers have grown to the spectral rank (and any ground-set bucket
/// size).
///
/// `Q^Y` is maintained *incrementally*: starting from `Q^∅ = I`, after
/// picking the item with restricted feature row `a = z_{j,E}` the
/// projector is downdated as
///
/// ```text
///   Q <- Q - (Q a)(Q a)^T / (a^T Q a),
/// ```
///
/// the standard Gram–Schmidt projector update — mathematically identical
/// to re-deriving [`conditional_q`] from scratch (which the tests assert),
/// `O(|E|^2)` per pick instead of `O(|Y|^3 + |Y|^2 |E|)`, and free of the
/// LU factorization the full rebuild needs.
#[derive(Debug, Clone, Default)]
pub struct ElementaryScratch {
    /// conditional projector `Q^Y` over the selected component (`ke x ke`)
    pub(crate) q: Matrix,
    /// `Q a` for the post-pick downdate
    qa: Vec<f64>,
    /// bucket / item scores (tree buckets or the full direct scan)
    pub(crate) scores: Vec<f64>,
    /// selected elementary component `E`
    pub(crate) e: Vec<usize>,
}

impl ElementaryScratch {
    pub fn new() -> ElementaryScratch {
        ElementaryScratch::default()
    }

    /// Preallocate for a spectral kernel of the given rank.
    pub fn with_rank(rank: usize) -> ElementaryScratch {
        ElementaryScratch {
            q: Matrix::zeros(rank, rank),
            qa: Vec::with_capacity(rank),
            scores: Vec::new(),
            e: Vec::with_capacity(rank),
        }
    }

    /// Start a fresh sample over a component of size `ke`: `Q <- I_ke`.
    pub(crate) fn reset_q(&mut self, ke: usize) {
        self.q.reset_identity(ke);
    }

    /// Condition the projector on a picked item whose *full* feature row
    /// (length = spectral rank) is `row`, restricted to the component `e`.
    pub(crate) fn condition_on(&mut self, row: &[f64], e: &[usize]) {
        let ke = e.len();
        self.qa.clear();
        for r in 0..ke {
            let qrow = self.q.row(r);
            let mut acc = 0.0;
            for c in 0..ke {
                acc += qrow[c] * row[e[c]];
            }
            self.qa.push(acc);
        }
        let mut p = 0.0;
        for r in 0..ke {
            p += row[e[r]] * self.qa[r];
        }
        // a numerically-dead pick (p ~ 0 through rounding) gets the same
        // guard as the Cholesky sweep: clamp the pivot away from zero
        let inv = 1.0 / p.max(1e-300);
        for r in 0..ke {
            let f = self.qa[r] * inv;
            if f == 0.0 {
                continue;
            }
            let qrow = self.q.row_mut(r);
            for c in 0..ke {
                qrow[c] -= f * self.qa[c];
            }
        }
    }
}

/// The conditional kernel `Q^Y = I_{|E|} - A^T (A A^T)^{-1} A` with
/// `A = Z_{Y,E}` (paper Eq. (11)).  `Q^∅ = I`.
pub fn conditional_q(z: &Matrix, y: &[usize], e: &[usize]) -> Matrix {
    let ke = e.len();
    let mut q = Matrix::identity(ke);
    if y.is_empty() {
        return q;
    }
    // A = Z_{Y,E}
    let mut a = Matrix::zeros(y.len(), ke);
    for (r, &item) in y.iter().enumerate() {
        for (c, &col) in e.iter().enumerate() {
            a[(r, c)] = z[(item, col)];
        }
    }
    let aat = a.matmul_t(&a);
    let inv = Lu::factor(&aat).inverse();
    // Q -= A^T inv A
    let tmp = a.t_matmul(&inv.matmul(&a));
    q = q.sub(&tmp);
    q
}

/// Conditional inclusion score of item `j`: `z_{j,E} Q z_{j,E}^T`.
#[inline]
pub fn item_score(z: &Matrix, j: usize, e: &[usize], q: &Matrix) -> f64 {
    let row = z.row(j);
    let ke = e.len();
    let mut acc = 0.0;
    for a in 0..ke {
        let za = row[e[a]];
        if za == 0.0 {
            continue;
        }
        let qrow = q.row(a);
        let mut inner = 0.0;
        for b in 0..ke {
            inner += qrow[b] * row[e[b]];
        }
        acc += za * inner;
    }
    acc
}

/// Step 2, direct `O(|E| M |E|^2)` version: linear scan over all items for
/// each of the `|E|` selections.  Exact; used as the tree's oracle and for
/// small M.
pub fn sample_elementary_direct(
    spectral: &SpectralDpp,
    e: &[usize],
    rng: &mut Xoshiro,
) -> Vec<usize> {
    let mut scratch = ElementaryScratch::with_rank(spectral.rank());
    sample_elementary_direct_with(spectral, e, &mut scratch, rng)
}

/// [`sample_elementary_direct`] with a caller-owned workspace: the
/// incremental projector keeps the per-pick cost at `O(M |E|^2)` with zero
/// heap allocation in the selection loop.
pub fn sample_elementary_direct_with(
    spectral: &SpectralDpp,
    e: &[usize],
    scratch: &mut ElementaryScratch,
    rng: &mut Xoshiro,
) -> Vec<usize> {
    let m = spectral.m();
    let z = &spectral.vecs;
    let mut y: Vec<usize> = Vec::with_capacity(e.len());
    scratch.reset_q(e.len());
    for _ in 0..e.len() {
        let j = {
            let ElementaryScratch { q, scores, .. } = &mut *scratch;
            // scores over all items; total mass = |E| - |Y|
            scores.clear();
            for item in 0..m {
                scores.push(item_score(z, item, e, q).max(0.0));
            }
            rng.weighted(scores)
        };
        scratch.condition_on(z.row(j), e);
        y.push(j);
    }
    y.sort_unstable();
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::{probability, NdppKernel, Proposal};
    use crate::util::prop;

    fn spectral_fixture(seed: u64, m: usize, k: usize) -> SpectralDpp {
        let mut rng = Xoshiro::seeded(seed);
        let kernel = NdppKernel::random_ondpp(m, k, &mut rng);
        Proposal::build(&kernel).spectral()
    }

    #[test]
    fn select_elementary_respects_probabilities() {
        let lambda = vec![0.0, 1.0, 9.0];
        let mut rng = Xoshiro::seeded(31);
        let n = 30_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            for i in select_elementary(&lambda, &mut rng) {
                counts[i] += 1;
            }
        }
        assert_eq!(counts[0], 0);
        let f1 = counts[1] as f64 / n as f64;
        let f2 = counts[2] as f64 / n as f64;
        assert!((f1 - 0.5).abs() < 0.02, "f1={f1}");
        assert!((f2 - 0.9).abs() < 0.02, "f2={f2}");
    }

    #[test]
    fn scores_sum_to_remaining_count() {
        prop::check("elem_trace", 10, |g| {
            let s = spectral_fixture(g.seed, 16, 4);
            let mut rng = Xoshiro::seeded(g.seed ^ 0xABCD);
            let e: Vec<usize> = (0..s.rank()).filter(|_| rng.uniform() < 0.6).collect();
            if e.is_empty() {
                return;
            }
            let mut y: Vec<usize> = Vec::new();
            for step in 0..e.len() {
                let q = conditional_q(&s.vecs, &y, &e);
                let total: f64 = (0..s.m()).map(|j| item_score(&s.vecs, j, &e, &q)).sum();
                let want = (e.len() - step) as f64;
                assert!((total - want).abs() < 1e-6, "step={step} total={total}");
                // greedily pick the max-score item to keep the test
                // deterministic
                let j = (0..s.m())
                    .max_by(|&a, &b| {
                        item_score(&s.vecs, a, &e, &q)
                            .partial_cmp(&item_score(&s.vecs, b, &e, &q))
                            .unwrap()
                    })
                    .unwrap();
                y.push(j);
            }
        });
    }

    #[test]
    fn incremental_projector_matches_direct_conditional_q() {
        // the scratch's rank-1 downdates must track the from-scratch
        // projection `I - A^T (A A^T)^{-1} A` pick after pick
        prop::check("elem_incremental_q", 8, |g| {
            let s = spectral_fixture(g.seed, 14, 4);
            let e: Vec<usize> = (0..s.rank()).collect();
            let mut scratch = ElementaryScratch::with_rank(s.rank());
            scratch.reset_q(e.len());
            let mut y: Vec<usize> = Vec::new();
            for _ in 0..e.len() {
                // greedily pick the max-score item: deterministic, and the
                // largest pivot keeps both computations well conditioned
                let j = (0..s.m())
                    .filter(|j| !y.contains(j))
                    .max_by(|&a, &b| {
                        item_score(&s.vecs, a, &e, &scratch.q)
                            .partial_cmp(&item_score(&s.vecs, b, &e, &scratch.q))
                            .unwrap()
                    })
                    .unwrap();
                scratch.condition_on(s.vecs.row(j), &e);
                y.push(j);
                let want = conditional_q(&s.vecs, &y, &e);
                for a in 0..e.len() {
                    for b in 0..e.len() {
                        assert!(
                            (scratch.q[(a, b)] - want[(a, b)]).abs() < 1e-7,
                            "|Y|={} a={a} b={b} got={} want={}",
                            y.len(),
                            scratch.q[(a, b)],
                            want[(a, b)]
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn elementary_sample_has_size_e() {
        let s = spectral_fixture(42, 20, 4);
        let mut rng = Xoshiro::seeded(7);
        for _ in 0..20 {
            let e = select_elementary(&s.lambda, &mut rng);
            let y = sample_elementary_direct(&s, &e, &mut rng);
            assert_eq!(y.len(), e.len());
            // distinct items
            let mut yy = y.clone();
            yy.dedup();
            assert_eq!(yy.len(), y.len());
        }
    }

    #[test]
    fn two_stage_sampling_matches_dpp_distribution() {
        // full pipeline (select E, sample elementary) vs enumerated
        // probabilities of the symmetric proposal kernel
        let mut rng = Xoshiro::seeded(33);
        let kernel = NdppKernel::random_ondpp(6, 2, &mut rng);
        let proposal = Proposal::build(&kernel);
        let s = proposal.spectral();
        let want = probability::enumerate_probs_dense(&proposal.dense_lhat());
        let n = 40_000;
        let mut counts = vec![0.0; 1 << 6];
        for _ in 0..n {
            let e = select_elementary(&s.lambda, &mut rng);
            let y = sample_elementary_direct(&s, &e, &mut rng);
            let mut mask = 0usize;
            for i in y {
                mask |= 1 << i;
            }
            counts[mask] += 1.0;
        }
        for c in &mut counts {
            *c /= n as f64;
        }
        let d = crate::sampler::test_support::tv(&counts, &want);
        assert!(d < 0.03, "tv={d}");
    }
}
