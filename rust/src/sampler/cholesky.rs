//! Linear-time Cholesky-based NDPP sampler (paper §3, Algorithm 1 RHS).
//!
//! Sweeps the M items once.  The running conditional marginal of item `i`
//! is the bilinear form `z_i^T Q z_i` where `Q` is a `2K x 2K` inner matrix
//! initialized to `W` (the marginal-kernel inner matrix) and downdated by a
//! rank-1 correction after every inclusion/exclusion decision (Eqs. (4),
//! (5)):
//!
//! ```text
//!   p_i = z_i^T Q z_i
//!   Q  <- Q - (Q z_i)(z_i^T Q) / (p_i            )   if i included
//!   Q  <- Q - (Q z_i)(z_i^T Q) / (p_i - 1        )   if i excluded
//! ```
//!
//! Per item: one `2K x 2K` mat-vec + rank-1 update = `O(K^2)`; total
//! `O(M K^2)` time, `O(M K)` memory — versus `O(M^3)`/`O(M^2)` for the
//! dense variant ([`crate::sampler::DenseCholeskySampler`]).

use crate::linalg::Matrix;
use crate::ndpp::{MarginalKernel, NdppKernel};
use crate::rng::Xoshiro;
use crate::sampler::Sampler;

/// Owned-or-borrowed marginal kernel, so the coordinator can share one
/// preprocessed `MarginalKernel` across many concurrent samplers without
/// cloning the `M x 2K` factor.
enum MarginalSource<'a> {
    Owned(Box<MarginalKernel>),
    Borrowed(&'a MarginalKernel),
}

impl MarginalSource<'_> {
    #[inline]
    fn get(&self) -> &MarginalKernel {
        match self {
            MarginalSource::Owned(m) => m,
            MarginalSource::Borrowed(m) => m,
        }
    }
}

/// Reusable workspace for the linear-time sweep — the *Scratch* half of
/// the Prepared/Scratch split (the immutable *Prepared* half being the
/// shared [`MarginalKernel`]).  One per worker thread; follows a model's
/// `2K` via [`CholeskyScratch::ensure`] without reallocating in steady
/// state.
#[derive(Debug, Clone, Default)]
pub struct CholeskyScratch {
    /// Q matrix reused across samples
    q: Matrix,
    /// Q z_i
    qz: Vec<f64>,
    /// z_i^T Q
    zq: Vec<f64>,
}

impl CholeskyScratch {
    pub fn new() -> CholeskyScratch {
        CholeskyScratch::default()
    }

    /// Sized for one marginal kernel.
    pub fn for_marginal(marginal: &MarginalKernel) -> CholeskyScratch {
        let mut s = CholeskyScratch::new();
        s.ensure(marginal.k2());
        s
    }

    /// Make the buffers `k2`-sized (no-op when already right).
    pub fn ensure(&mut self, k2: usize) {
        if self.q.rows != k2 || self.q.cols != k2 {
            self.q.reset_zeros(k2, k2);
            self.qz.clear();
            self.qz.resize(k2, 0.0);
            self.zq.clear();
            self.zq.resize(k2, 0.0);
        }
    }
}

/// Draw one sample and its log-probability from a shared prepared
/// [`MarginalKernel`] using a caller-owned workspace — the coordinator's
/// hot path: any number of workers can call this concurrently on the same
/// marginal with their own scratches, no locking, no allocation beyond the
/// returned subset.
pub fn sample_with_logprob_into(
    marginal: &MarginalKernel,
    scratch: &mut CholeskyScratch,
    rng: &mut Xoshiro,
) -> (Vec<usize>, f64) {
    sweep_skipping(&marginal.z, &marginal.w, scratch, &[], rng)
}

/// The Cholesky sweep over an explicit `(Z, W)` pair, skipping the sorted
/// items in `skip` entirely (no uniform draw, no rank-1 update) — the
/// conditional sampler's entry point, where `W` is the conditioned
/// marginal inner matrix and `skip` the observed basket.  With an empty
/// `skip` this is byte-identical to the unconditional sweep (identical
/// arithmetic on the identical rng stream).
pub(crate) fn sweep_skipping(
    z: &Matrix,
    w: &Matrix,
    scratch: &mut CholeskyScratch,
    skip: &[usize],
    rng: &mut Xoshiro,
) -> (Vec<usize>, f64) {
    let m = z.rows;
    let k2 = z.cols;
    scratch.ensure(k2);
    scratch.q.data.copy_from_slice(&w.data);
    let mut out = Vec::new();
    let mut logp = 0.0;
    let mut skip_at = 0usize;

    for i in 0..m {
        if skip_at < skip.len() && skip[skip_at] == i {
            skip_at += 1;
            continue;
        }
        let zi = z.row(i);
        // fused pass over Q's rows: qz[r] = <Q_r, z_i> and
        // zq += z_i[r] * Q_r  (one traversal instead of two — §Perf)
        scratch.zq.iter_mut().for_each(|x| *x = 0.0);
        for (r, &zr) in zi.iter().enumerate() {
            let qrow = scratch.q.row(r);
            let mut acc = 0.0;
            if zr != 0.0 {
                for c in 0..k2 {
                    let q_rc = qrow[c];
                    acc += q_rc * zi[c];
                    scratch.zq[c] += zr * q_rc;
                }
            } else {
                for c in 0..k2 {
                    acc += qrow[c] * zi[c];
                }
            }
            scratch.qz[r] = acc;
        }
        let p = crate::linalg::matrix::dot(zi, &scratch.qz);
        let u = rng.uniform();
        let take = u <= p;
        let denom = if take {
            p.max(1e-300)
        } else {
            (p - 1.0).min(-1e-300)
        };
        logp += if take { p.max(1e-300).ln() } else { (1.0 - p).max(1e-300).ln() };
        if take {
            out.push(i);
        }
        // Q -= qz zq^T / denom
        let inv = 1.0 / denom;
        for r in 0..k2 {
            let f = scratch.qz[r] * inv;
            if f == 0.0 {
                continue;
            }
            let qrow = scratch.q.row_mut(r);
            for c in 0..k2 {
                qrow[c] -= f * scratch.zq[c];
            }
        }
    }
    (out, logp)
}

/// Preprocessed linear-time sampler.  Construction costs `O(M K^2)` (one
/// Gram matrix + one `2K x 2K` inverse); each sample costs `O(M K^2)`.
/// Bundles the shared prepared marginal with a private
/// [`CholeskyScratch`]; the coordinator instead shares one marginal across
/// workers and gives each its own scratch via
/// [`sample_with_logprob_into`].
pub struct CholeskySampler<'a> {
    marginal: MarginalSource<'a>,
    scratch: CholeskyScratch,
}

impl<'a> CholeskySampler<'a> {
    pub fn new(kernel: &NdppKernel) -> CholeskySampler<'static> {
        CholeskySampler::from_owned(MarginalKernel::build(kernel))
    }

    /// Take ownership of a precomputed marginal kernel.
    pub fn from_owned(marginal: MarginalKernel) -> CholeskySampler<'static> {
        let scratch = CholeskyScratch::for_marginal(&marginal);
        CholeskySampler {
            marginal: MarginalSource::Owned(Box::new(marginal)),
            scratch,
        }
    }

    /// Borrow a shared preprocessed marginal kernel (coordinator path).
    pub fn from_marginal(marginal: &'a MarginalKernel) -> CholeskySampler<'a> {
        CholeskySampler {
            scratch: CholeskyScratch::for_marginal(marginal),
            marginal: MarginalSource::Borrowed(marginal),
        }
    }

    pub fn m(&self) -> usize {
        self.marginal.get().m()
    }

    /// `log det(L+I)` of the underlying kernel (for log-prob reporting).
    pub fn logdet_l_plus_i(&self) -> f64 {
        self.marginal.get().logdet_l_plus_i
    }

    /// Draw one sample together with its log-probability under the NDPP.
    pub fn sample_with_logprob(&mut self, rng: &mut Xoshiro) -> (Vec<usize>, f64) {
        sample_with_logprob_into(self.marginal.get(), &mut self.scratch, rng)
    }
}

impl Sampler for CholeskySampler<'_> {
    fn sample(&mut self, rng: &mut Xoshiro) -> Vec<usize> {
        self.sample_with_logprob(rng).0
    }

    fn name(&self) -> &'static str {
        "cholesky-lowrank"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::probability;
    use crate::sampler::test_support::{empirical, tv};
    use crate::util::prop;

    #[test]
    fn distribution_matches_enumeration() {
        // exactness against the exponential-time oracle on tiny M
        let mut rng = Xoshiro::seeded(11);
        let kernel = NdppKernel::random_ondpp(6, 2, &mut rng);
        let want = probability::enumerate_probs(&kernel);
        let mut s = CholeskySampler::new(&kernel);
        let got = empirical(&mut s, 6, 40_000, &mut rng);
        let d = tv(&got, &want);
        assert!(d < 0.03, "tv={d}");
    }

    #[test]
    fn distribution_matches_enumeration_nonorthogonal() {
        let mut rng = Xoshiro::seeded(12);
        let kernel = NdppKernel::random_ndpp(6, 2, &mut rng);
        let want = probability::enumerate_probs(&kernel);
        let mut s = CholeskySampler::new(&kernel);
        let got = empirical(&mut s, 6, 40_000, &mut rng);
        let d = tv(&got, &want);
        assert!(d < 0.03, "tv={d}");
    }

    #[test]
    fn marginal_frequencies_match_kernel_diag() {
        prop::check("chol_marginals", 3, |g| {
            let mut rng = Xoshiro::seeded(g.seed);
            let m = 12;
            let kernel = NdppKernel::random_ondpp(m, 4, &mut rng);
            let mk = crate::ndpp::MarginalKernel::build(&kernel);
            let want = mk.marginals();
            let mut s = CholeskySampler::new(&kernel);
            let n = 20_000;
            let mut counts = vec![0.0; m];
            for _ in 0..n {
                for i in s.sample(&mut rng) {
                    counts[i] += 1.0;
                }
            }
            for i in 0..m {
                let freq = counts[i] / n as f64;
                let sd = (want[i] * (1.0 - want[i]) / n as f64).sqrt().max(1e-4);
                assert!(
                    (freq - want[i]).abs() < 5.0 * sd + 0.01,
                    "i={i} freq={freq} want={}",
                    want[i]
                );
            }
        });
    }

    #[test]
    fn logprob_matches_direct_computation() {
        let mut rng = Xoshiro::seeded(13);
        let kernel = NdppKernel::random_ondpp(10, 2, &mut rng);
        let mut s = CholeskySampler::new(&kernel);
        for _ in 0..20 {
            let (y, lp) = s.sample_with_logprob(&mut rng);
            let direct = probability::log_prob(&kernel, s.logdet_l_plus_i(), &y);
            assert!(
                (lp - direct).abs() < 1e-6 * (1.0 + direct.abs()),
                "lp={lp} direct={direct} y={y:?}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng_k = Xoshiro::seeded(14);
        let kernel = NdppKernel::random_ondpp(30, 4, &mut rng_k);
        let mut s1 = CholeskySampler::new(&kernel);
        let mut s2 = CholeskySampler::new(&kernel);
        let mut r1 = Xoshiro::seeded(99);
        let mut r2 = Xoshiro::seeded(99);
        for _ in 0..5 {
            assert_eq!(s1.sample(&mut r1), s2.sample(&mut r2));
        }
    }

    #[test]
    fn sample_sizes_bounded_by_rank() {
        // |Y| <= rank(L) = 2K almost surely
        let mut rng = Xoshiro::seeded(15);
        let kernel = NdppKernel::random_ondpp(50, 4, &mut rng);
        let mut s = CholeskySampler::new(&kernel);
        for _ in 0..50 {
            let y = s.sample(&mut rng);
            assert!(y.len() <= 8, "|Y|={}", y.len());
        }
    }
}
