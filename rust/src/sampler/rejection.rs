//! Sublinear-time rejection sampler (paper §4, Algorithm 2).
//!
//! Draw `Y` from the symmetric proposal DPP `L̂` (tree-accelerated), accept
//! with probability `det(L_Y) / det(L̂_Y)` (well-defined and `<= 1` by
//! Theorem 1).  The number of proposal draws is geometric with mean
//! `U = det(L̂+I)/det(L+I)`; for ONDPP kernels Theorem 2 bounds `U` by
//! `prod_j (1 + 2σ_j/(σ_j²+1))` — independent of M.

use crate::ndpp::{probability, NdppKernel, Proposal};
use crate::rng::Xoshiro;
use crate::sampler::elementary::ElementaryScratch;
use crate::sampler::{SampleTree, Sampler};

/// Safety valve: proposals per sample before giving up (a correctly
/// constructed ONDPP with the paper's regularizer keeps U in the tens).
const MAX_PROPOSALS: usize = 5_000_000;

/// Tree-based rejection sampler.  Borrow-based: the kernel, proposal, and
/// tree are shared, read-only preprocessing products (the coordinator
/// builds them once per model and shares them across worker threads).
pub struct RejectionSampler<'a> {
    kernel: &'a NdppKernel,
    proposal: &'a Proposal,
    tree: &'a SampleTree,
    /// reusable descent workspace (the Scratch half of the
    /// Prepared/Scratch split; the borrowed fields above are the Prepared
    /// half)
    scratch: ElementaryScratch,
    /// proposals drawn for the most recent sample (>= 1)
    pub last_proposals: usize,
    /// running totals for rejection-rate reporting
    pub total_proposals: u64,
    pub total_samples: u64,
}

impl<'a> RejectionSampler<'a> {
    pub fn new(
        kernel: &'a NdppKernel,
        proposal: &'a Proposal,
        tree: &'a SampleTree,
    ) -> RejectionSampler<'a> {
        let scratch = ElementaryScratch::with_rank(tree.spectral().rank());
        RejectionSampler::with_scratch(kernel, proposal, tree, scratch)
    }

    /// Revive a worker-cached workspace (see [`RejectionSampler::
    /// into_scratch`]): lets the coordinator keep one warm scratch per
    /// (worker, model) across request batches.
    pub fn with_scratch(
        kernel: &'a NdppKernel,
        proposal: &'a Proposal,
        tree: &'a SampleTree,
        scratch: ElementaryScratch,
    ) -> RejectionSampler<'a> {
        assert_eq!(kernel.m(), proposal.m());
        assert_eq!(tree.m(), kernel.m());
        RejectionSampler {
            kernel,
            proposal,
            tree,
            scratch,
            last_proposals: 0,
            total_proposals: 0,
            total_samples: 0,
        }
    }

    /// Hand the workspace back for caching.
    pub fn into_scratch(self) -> ElementaryScratch {
        self.scratch
    }

    /// Mean proposals per accepted sample observed so far.
    pub fn observed_rejection_rate(&self) -> f64 {
        if self.total_samples == 0 {
            0.0
        } else {
            self.total_proposals as f64 / self.total_samples as f64
        }
    }

    /// Theoretical expectation `det(L̂+I)/det(L+I)`.
    pub fn expected_rejection_rate(&self) -> f64 {
        self.proposal.expected_rejections()
    }
}

impl Sampler for RejectionSampler<'_> {
    fn sample(&mut self, rng: &mut Xoshiro) -> Vec<usize> {
        for attempt in 1..=MAX_PROPOSALS {
            let y = self.tree.sample_dpp_with(&mut self.scratch, rng);
            let accept = probability::acceptance_prob(self.kernel, self.proposal, &y);
            if rng.uniform() <= accept {
                self.last_proposals = attempt;
                self.total_proposals += attempt as u64;
                self.total_samples += 1;
                return y;
            }
        }
        panic!(
            "rejection sampler exceeded {MAX_PROPOSALS} proposals — \
             expected rate {:.3e}; kernel is unsuitable for rejection \
             sampling (consider the gamma regularizer, paper Eq. (14))",
            self.expected_rejection_rate()
        );
    }

    fn name(&self) -> &'static str {
        "tree-rejection"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::probability::enumerate_probs;
    use crate::sampler::test_support::{empirical, tv};
    use crate::sampler::TreeConfig;

    fn fixture(seed: u64, m: usize, k: usize) -> (NdppKernel, Proposal) {
        let mut rng = Xoshiro::seeded(seed);
        let kernel = NdppKernel::random_ondpp(m, k, &mut rng);
        let proposal = Proposal::build(&kernel);
        (kernel, proposal)
    }

    #[test]
    fn distribution_matches_enumeration() {
        let (kernel, proposal) = fixture(51, 6, 2);
        let tree = SampleTree::build(&proposal.spectral(), TreeConfig { leaf_size: 2 });
        let mut s = RejectionSampler::new(&kernel, &proposal, &tree);
        let want = enumerate_probs(&kernel);
        let mut rng = Xoshiro::seeded(52);
        let got = empirical(&mut s, 6, 30_000, &mut rng);
        let d = tv(&got, &want);
        assert!(d < 0.035, "tv={d}");
    }

    #[test]
    fn observed_rejections_match_theory() {
        let (kernel, proposal) = fixture(53, 24, 4);
        let tree = SampleTree::build(&proposal.spectral(), TreeConfig::default());
        let mut s = RejectionSampler::new(&kernel, &proposal, &tree);
        let mut rng = Xoshiro::seeded(54);
        let n = 3000;
        for _ in 0..n {
            s.sample(&mut rng);
        }
        let observed = s.observed_rejection_rate();
        let expected = s.expected_rejection_rate();
        // geometric mean-of-means: se ~ sqrt(U(U-1)/n)
        let se = (expected * (expected - 1.0).max(0.0) / n as f64).sqrt();
        assert!(
            (observed - expected).abs() < 5.0 * se + 0.05 * expected + 0.05,
            "observed={observed} expected={expected}"
        );
    }

    #[test]
    fn matches_cholesky_sampler_distribution() {
        // the two independent sampler families agree on a nontrivial kernel
        let (kernel, proposal) = fixture(55, 7, 2);
        let tree = SampleTree::build(&proposal.spectral(), TreeConfig { leaf_size: 1 });
        let mut rej = RejectionSampler::new(&kernel, &proposal, &tree);
        let mut chol = crate::sampler::CholeskySampler::new(&kernel);
        let mut rng = Xoshiro::seeded(56);
        let p = empirical(&mut rej, 7, 30_000, &mut rng);
        let q = empirical(&mut chol, 7, 30_000, &mut rng);
        let d = tv(&p, &q);
        assert!(d < 0.04, "tv={d}");
    }

    #[test]
    fn works_with_zero_sigma_kernel() {
        // sigma = 0 collapses the skew part: proposal == target, U == 1,
        // every proposal accepted
        let mut rng = Xoshiro::seeded(57);
        let mut kernel = NdppKernel::random_ondpp(16, 4, &mut rng);
        kernel.sigma = vec![0.0, 0.0];
        let proposal = Proposal::build(&kernel);
        assert!((proposal.expected_rejections() - 1.0).abs() < 1e-9);
        let tree = SampleTree::build(&proposal.spectral(), TreeConfig::default());
        let mut s = RejectionSampler::new(&kernel, &proposal, &tree);
        for _ in 0..50 {
            s.sample(&mut rng);
            assert_eq!(s.last_proposals, 1);
        }
    }
}
