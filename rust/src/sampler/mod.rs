//! Exact NDPP samplers.
//!
//! | sampler | paper | complexity / sample | notes |
//! |---|---|---|---|
//! | [`DenseCholeskySampler`] | Alg 1 (LHS), Poulson 2019 | `O(M^3)` | baseline, small M only |
//! | [`CholeskySampler`] | Alg 1 (RHS), §3 | `O(M K^2)` | linear-time, low-rank |
//! | [`RejectionSampler`] | Alg 2, §4 | `O((K + k^3 log M + k^4) U)` | sublinear, needs proposal + tree |
//!
//! plus the building blocks: [`elementary`] (elementary-DPP sampling from a
//! spectral kernel, the mixture components of Eq. (10)) and [`tree`]
//! (Gillenwater et al. 2019's binary tree with the paper's improved
//! `O(k^2)`-per-node inner products, Proposition 1).
//!
//! All samplers implement [`Sampler`] and draw randomness from an explicit
//! [`Xoshiro`] stream, so every sample is reproducible from `(kernel, seed)`.

pub mod cholesky;
pub mod dense;
pub mod elementary;
pub mod fixed_size;
pub mod rejection;
pub mod tree;

pub use cholesky::CholeskySampler;
pub use dense::DenseCholeskySampler;
pub use fixed_size::{sample_fixed_size, size_distribution};
pub use rejection::RejectionSampler;
pub use tree::{SampleTree, TreeConfig};

use crate::rng::Xoshiro;

/// A sampler draws subsets of `[M]` distributed as `Pr(Y) ∝ det(L_Y)`.
pub trait Sampler {
    /// Draw one subset (sorted ascending).
    fn sample(&mut self, rng: &mut Xoshiro) -> Vec<usize>;

    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Shared distribution-exactness machinery for sampler tests.

    use super::Sampler;
    use crate::rng::Xoshiro;

    /// Empirical subset distribution over bitmasks for tiny M.
    pub fn empirical(
        sampler: &mut dyn Sampler,
        m: usize,
        n: usize,
        rng: &mut Xoshiro,
    ) -> Vec<f64> {
        let mut counts = vec![0.0; 1 << m];
        for _ in 0..n {
            let y = sampler.sample(rng);
            let mut mask = 0usize;
            for i in y {
                mask |= 1 << i;
            }
            counts[mask] += 1.0;
        }
        for c in &mut counts {
            *c /= n as f64;
        }
        counts
    }

    /// Total-variation distance between two distributions.
    pub fn tv(p: &[f64], q: &[f64]) -> f64 {
        0.5 * p
            .iter()
            .zip(q)
            .map(|(a, b)| (a - b).abs())
            .sum::<f64>()
    }
}
