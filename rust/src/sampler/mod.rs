//! Exact NDPP samplers.
//!
//! | sampler | paper | complexity / sample | notes |
//! |---|---|---|---|
//! | [`DenseCholeskySampler`] | Alg 1 (LHS), Poulson 2019 | `O(M^3)` | baseline, small M only |
//! | [`CholeskySampler`] | Alg 1 (RHS), §3 | `O(M K^2)` | linear-time, low-rank |
//! | [`RejectionSampler`] | Alg 2, §4 | `O((K + k^3 log M + k^4) U)` | sublinear, needs proposal + tree |
//! | [`McmcSampler`] | Han et al. 2022 follow-up | `O((k^2 + k K + R^2 log M) · steps)` | fixed-size k-NDPP, immune to diverging `U`; tree-driven proposals by default |
//! | [`VariableMcmcSampler`] | Han et al. 2022 follow-up | `O((k^2 + k K + R^2 log M) · steps)` | unconstrained cardinality, same chain machinery |
//!
//! plus the building blocks: [`elementary`] (elementary-DPP sampling from a
//! spectral kernel, the mixture components of Eq. (10)) and [`tree`]
//! (Gillenwater et al. 2019's binary tree with the paper's improved
//! `O(k^2)`-per-node inner products, Proposition 1), and the
//! [`conditional`] subsystem, which drives all three fast families from a
//! Schur-complement [`crate::ndpp::ConditionedKernel`] for
//! basket-completion workloads (observed items `J`, sample `Y ⊇ J`).
//!
//! All samplers implement [`Sampler`] and draw randomness from an explicit
//! [`Xoshiro`] stream, so every sample is reproducible from `(kernel, seed)`.
//!
//! ## The Prepared/Scratch split
//!
//! Every sampler is factored into two halves, mirroring the paper's
//! one-time-preprocessing / cheap-per-sample structure:
//!
//! * an immutable **Prepared** core — `Send + Sync` data built once per
//!   model ([`crate::ndpp::MarginalKernel`], [`crate::ndpp::Proposal`] +
//!   [`SampleTree`], [`dense::DensePrepared`], the
//!   [`mcmc::try_build_seed`] warm start) that any number of worker
//!   threads sample from concurrently with zero locking, and
//! * a reusable **Scratch** workspace ([`cholesky::CholeskyScratch`],
//!   [`elementary::ElementaryScratch`], [`dense::DenseScratch`], the step
//!   buffers inside [`crate::ndpp::probability::IncrementalMinor`]) — one
//!   per worker, reused across requests so the per-sample hot loops
//!   perform no heap allocation in steady state.
//!
//! The `*Sampler` structs below bundle one of each for convenience; the
//! coordinator ([`crate::coordinator::service`]) instead shares each
//! model's Prepared half across its shard workers and keeps a warm Scratch
//! per (worker, model).

pub mod cholesky;
pub mod conditional;
pub mod dense;
pub mod elementary;
pub mod fixed_size;
pub mod mcmc;
pub mod rejection;
pub mod tree;

pub use cholesky::{CholeskySampler, CholeskyScratch};
pub use conditional::{ConditionalPrepared, ConditionalScratch};
pub use dense::{DenseCholeskySampler, DensePrepared, DenseScratch};
pub use elementary::ElementaryScratch;
pub use fixed_size::{sample_fixed_size, size_distribution};
pub use mcmc::{McmcConfig, McmcSampler, ProposalKind, VariableMcmcSampler};
pub use rejection::RejectionSampler;
pub use tree::{SampleTree, TreeConfig};

use crate::rng::Xoshiro;

/// A sampler draws subsets of `[M]` distributed as `Pr(Y) ∝ det(L_Y)`.
pub trait Sampler {
    /// Draw one subset (sorted ascending).
    fn sample(&mut self, rng: &mut Xoshiro) -> Vec<usize>;

    /// Human-readable name for reports.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
pub(crate) mod test_support {
    //! Shared distribution-exactness machinery for sampler tests — now a
    //! thin alias for the public [`crate::util::testing`] module, kept so
    //! in-module tests read naturally.

    pub use crate::util::testing::{empirical, tv};
}
