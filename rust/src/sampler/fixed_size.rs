//! Fixed-size (k-NDPP) sampling — the paper's §7 "future work" extension.
//!
//! A k-NDPP is the NDPP conditioned on `|Y| = k`:
//! `Pr(Y) ∝ det(L_Y) · 1[|Y| = k]`.  Conditioning a rejection-style exact
//! sampler on a measurable event keeps it exact, so the simplest correct
//! construction is size-rejection around any exact NDPP sampler: draw until
//! the size matches.  The expected number of draws is `1 / Pr(|Y| = k)`,
//! which is small when `k` is near the mode of the size distribution —
//! exactly the regime recommender workloads use ("give me 5 diverse
//! items").
//!
//! [`size_distribution`] exposes `Pr(|Y| = k)` for the **proposal** DPP via
//! the elementary symmetric polynomials of its eigenvalues (Kulesza &
//! Taskar 2012, §5.2), which callers use to pick a feasible `k` and to
//! bound the retry count a priori.  (For the nonsymmetric target the exact
//! size law has no product form, but the proposal's is an excellent guide:
//! both share the symmetric part's spectrum.)

use anyhow::{bail, Result};

use crate::rng::Xoshiro;
use crate::sampler::Sampler;

/// `Pr(|Y| = k)` for a symmetric DPP with kernel eigenvalues `lambda`,
/// for all `k = 0..=n`, via the stable normalized recurrence on elementary
/// symmetric polynomials of `lambda_i / (1 + lambda_i)`.
pub fn size_distribution(lambda: &[f64]) -> Vec<f64> {
    let n = lambda.len();
    // e_k over p_i = lambda/(1+lambda), times prod (1 - p_i) — i.e. the
    // Poisson-binomial distribution of the independent Bernoulli(p_i)
    // eigenvalue selections (Eq. (10)'s mixture weights).
    let mut dist = vec![0.0; n + 1];
    dist[0] = 1.0;
    for &l in lambda {
        let p = l / (1.0 + l);
        for k in (1..=n).rev() {
            dist[k] = dist[k] * (1.0 - p) + dist[k - 1] * p;
        }
        dist[0] *= 1.0 - p;
    }
    dist
}

/// Draw one size-`k` sample by conditioning `inner` on `|Y| = k`.
///
/// `max_tries` bounds the geometric retry loop; pick it from
/// `size_distribution` (e.g. `10 / Pr(|Y|=k)`).
pub fn sample_fixed_size(
    inner: &mut dyn Sampler,
    k: usize,
    max_tries: usize,
    rng: &mut Xoshiro,
) -> Result<Vec<usize>> {
    for _ in 0..max_tries {
        let y = inner.sample(rng);
        if y.len() == k {
            return Ok(y);
        }
    }
    bail!(
        "no size-{k} sample in {max_tries} draws from '{}' — k is far from \
         the size distribution's mode; check size_distribution()",
        inner.name()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::{probability, NdppKernel, Proposal};
    use crate::sampler::CholeskySampler;

    #[test]
    fn size_distribution_is_poisson_binomial() {
        // two eigenvalues 1.0 => p = 1/2 each: sizes 0,1,2 w.p. 1/4,1/2,1/4
        let d = size_distribution(&[1.0, 1.0]);
        assert!((d[0] - 0.25).abs() < 1e-12);
        assert!((d[1] - 0.5).abs() < 1e-12);
        assert!((d[2] - 0.25).abs() < 1e-12);
        let total: f64 = d.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn size_distribution_matches_sampler_sizes() {
        let mut rng = Xoshiro::seeded(3);
        let kernel = NdppKernel::random_ondpp(24, 4, &mut rng);
        let proposal = Proposal::build(&kernel);
        let spectral = proposal.spectral();
        let want = size_distribution(&spectral.lambda);
        let tree =
            crate::sampler::SampleTree::build(&spectral, crate::sampler::TreeConfig::default());
        let n = 20_000;
        let mut counts = vec![0.0; spectral.rank() + 1];
        for _ in 0..n {
            counts[tree.sample_dpp(&mut rng).len()] += 1.0;
        }
        for (k, &w) in want.iter().enumerate() {
            let f = counts[k] / n as f64;
            let sd = (w * (1.0 - w) / n as f64).sqrt().max(1e-4);
            assert!((f - w).abs() < 5.0 * sd + 0.01, "k={k} f={f} w={w}");
        }
    }

    #[test]
    fn fixed_size_distribution_matches_conditioned_enumeration() {
        let m = 6;
        let target_k = 2;
        let mut rng = Xoshiro::seeded(5);
        let kernel = NdppKernel::random_ondpp(m, 2, &mut rng);
        // enumerate Pr(Y | |Y| = target_k)
        let probs = probability::enumerate_probs(&kernel);
        let mut want = vec![0.0; 1 << m];
        let mut mass = 0.0;
        for (mask, &p) in probs.iter().enumerate() {
            if (mask as u32).count_ones() as usize == target_k {
                want[mask] = p;
                mass += p;
            }
        }
        for w in &mut want {
            *w /= mass;
        }
        let mut sampler = CholeskySampler::new(&kernel);
        let n = 20_000;
        let mut counts = vec![0.0; 1 << m];
        for _ in 0..n {
            let y = sample_fixed_size(&mut sampler, target_k, 10_000, &mut rng).unwrap();
            let mut mask = 0usize;
            for i in y {
                mask |= 1 << i;
            }
            counts[mask] += 1.0;
        }
        let tvd: f64 = 0.5
            * counts
                .iter()
                .zip(&want)
                .map(|(c, w)| (c / n as f64 - w).abs())
                .sum::<f64>();
        assert!(tvd < 0.04, "tv={tvd}");
    }

    #[test]
    fn infeasible_size_errors_cleanly() {
        let mut rng = Xoshiro::seeded(6);
        let kernel = NdppKernel::random_ondpp(16, 2, &mut rng);
        let mut sampler = CholeskySampler::new(&kernel);
        // rank is 4 => |Y| = 10 impossible
        assert!(sample_fixed_size(&mut sampler, 10, 200, &mut rng).is_err());
    }
}
