//! Dense `O(M^3)` Cholesky-based sampler (paper Algorithm 1 LHS; Poulson
//! 2019, Algorithm 1) — the pre-existing baseline the paper improves on.
//!
//! Materializes the full `M x M` marginal kernel and downdates the
//! trailing principal block after every decision:
//!
//! ```text
//!   p_i = K_ii;  include w.p. p_i (else p_i <- p_i - 1)
//!   K_A <- K_A - K_{A,i} K_{i,A} / p_i     for A = {i+1..M}
//! ```
//!
//! Kept (a) as the baseline for Table 3 / Fig 2 comparisons at small M,
//! and (b) as an independent correctness oracle: with the same uniform
//! stream it must make exactly the decisions of the low-rank sampler.

use crate::linalg::{lu, Matrix};
use crate::ndpp::NdppKernel;
use crate::rng::Xoshiro;
use crate::sampler::Sampler;

/// Immutable prepared core of the dense sampler: the full `M x M`
/// marginal kernel `K = I - (L+I)^{-1}`.  `O(M^3)` to build, `O(M^2)`
/// memory; built at most once per model and shared read-only across
/// workers (the coordinator caches it lazily on the [`crate::coordinator::
/// ModelEntry`]).
pub struct DensePrepared {
    k: Matrix,
}

impl DensePrepared {
    pub fn build(kernel: &NdppKernel) -> DensePrepared {
        let m = kernel.m();
        let mut l_plus_i = kernel.dense_l();
        l_plus_i.add_diag(1.0);
        let inv = lu::inverse(&l_plus_i);
        DensePrepared { k: Matrix::identity(m).sub(&inv) }
    }

    pub fn m(&self) -> usize {
        self.k.rows
    }
}

/// Per-worker workspace: the `M x M` copy the sweep downdates in place.
#[derive(Debug, Clone, Default)]
pub struct DenseScratch {
    q: Matrix,
}

impl DenseScratch {
    pub fn new() -> DenseScratch {
        DenseScratch::default()
    }
}

/// One dense-sweep sample from a shared prepared kernel with a
/// caller-owned workspace (resized on first use / model change).
pub fn sample_into(
    prepared: &DensePrepared,
    scratch: &mut DenseScratch,
    rng: &mut Xoshiro,
) -> Vec<usize> {
    let m = prepared.m();
    if scratch.q.rows != m || scratch.q.cols != m {
        scratch.q.reset_zeros(m, m);
    }
    scratch.q.data.copy_from_slice(&prepared.k.data);
    let q = &mut scratch.q;
    let mut out = Vec::new();
    for i in 0..m {
        let mut p = q[(i, i)];
        let take = rng.uniform() <= p;
        if take {
            out.push(i);
            p = p.max(1e-300);
        } else {
            p = (p - 1.0).min(-1e-300);
        }
        // K_A -= K_{A,i} K_{i,A} / p  over the trailing block
        let inv = 1.0 / p;
        for r in (i + 1)..m {
            let f = q[(r, i)] * inv;
            if f == 0.0 {
                continue;
            }
            // row slice of K_{i, A}
            let (head, tail) = q.data.split_at_mut(r * m);
            let ki = &head[i * m..(i + 1) * m];
            let kr = &mut tail[..m];
            for c in (i + 1)..m {
                kr[c] -= f * ki[c];
            }
        }
    }
    out
}

/// Dense-marginal-kernel sampler.  Construction is `O(M^3)` (matrix
/// inverse), each sample is `O(M^3)`; memory `O(M^2)`.  Use only for
/// M up to a few thousand.  Bundles a private [`DensePrepared`] +
/// [`DenseScratch`]; the coordinator shares one prepared core and gives
/// each worker its own scratch via [`sample_into`].
pub struct DenseCholeskySampler {
    prepared: DensePrepared,
    scratch: DenseScratch,
}

impl DenseCholeskySampler {
    pub fn new(kernel: &NdppKernel) -> DenseCholeskySampler {
        DenseCholeskySampler {
            prepared: DensePrepared::build(kernel),
            scratch: DenseScratch::new(),
        }
    }

    pub fn m(&self) -> usize {
        self.prepared.m()
    }
}

impl Sampler for DenseCholeskySampler {
    fn sample(&mut self, rng: &mut Xoshiro) -> Vec<usize> {
        sample_into(&self.prepared, &mut self.scratch, rng)
    }

    fn name(&self) -> &'static str {
        "cholesky-dense"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::probability;
    use crate::sampler::cholesky::CholeskySampler;
    use crate::sampler::test_support::{empirical, tv};

    #[test]
    fn distribution_matches_enumeration() {
        let mut rng = Xoshiro::seeded(21);
        let kernel = NdppKernel::random_ondpp(6, 2, &mut rng);
        let want = probability::enumerate_probs(&kernel);
        let mut s = DenseCholeskySampler::new(&kernel);
        let got = empirical(&mut s, 6, 40_000, &mut rng);
        let d = tv(&got, &want);
        assert!(d < 0.03, "tv={d}");
    }

    #[test]
    fn lockstep_with_lowrank_sampler() {
        // identical uniform stream => identical decisions (numerics differ
        // only at ~1e-12, so decision flips are astronomically unlikely on
        // fixed seeds)
        let mut rng_k = Xoshiro::seeded(22);
        for trial in 0..5 {
            let kernel = NdppKernel::random_ondpp(24, 4, &mut rng_k);
            let mut dense = DenseCholeskySampler::new(&kernel);
            let mut lowrank = CholeskySampler::new(&kernel);
            let mut r1 = Xoshiro::seeded(1000 + trial);
            let mut r2 = Xoshiro::seeded(1000 + trial);
            assert_eq!(dense.sample(&mut r1), lowrank.sample(&mut r2), "trial={trial}");
        }
    }

    #[test]
    fn nonsymmetric_kernel_also_exact() {
        let mut rng = Xoshiro::seeded(23);
        let kernel = NdppKernel::random_ndpp(5, 2, &mut rng);
        let want = probability::enumerate_probs(&kernel);
        let mut s = DenseCholeskySampler::new(&kernel);
        let got = empirical(&mut s, 5, 30_000, &mut rng);
        assert!(tv(&got, &want) < 0.03);
    }
}
