//! MCMC up-down samplers with tree-driven proposals.
//!
//! The rejection sampler's cost is governed by `U = det(L̂+I)/det(L+I)`,
//! which explodes (`~2^{K/2}`) once the ONDPP orthogonality/regularization
//! that Theorem 2 relies on is relaxed — exactly the kernels the follow-up
//! paper *Scalable MCMC Sampling for Nonsymmetric Determinantal Point
//! Processes* (Han, Gartrell, Dohmatob, Karbasi 2022) targets with a
//! low-rank up-down random walk.  This module implements that walk for two
//! targets:
//!
//! ```text
//!   fixed size ([`McmcSampler`]):       Pr(Y) ∝ det(L_Y) · 1[|Y| = k]
//!   variable size ([`VariableMcmcSampler`]): Pr(Y) ∝ det(L_Y)
//! ```
//!
//! ## Proposals: uniform vs tree-driven
//!
//! The textbook chain proposes candidate items uniformly from the catalog,
//! so the probability of proposing any *useful* item — one carrying
//! proposal-DPP mass — shrinks like `O(K/M)` and mixing time scales with
//! `M`.  The tree-driven proposal ([`ProposalKind::Tree`], the default)
//! instead descends the registration-time [`SampleTree`] under the weight
//! matrix `W = diag(λ/(1+λ))`, drawing item `j` with probability
//! proportional to its proposal marginal `K̂_jj = z_j^T W z_j` in
//! `O(R^2 log M)` per draw.  The descent returns the **exact** probability
//! of the item it drew (the walk is single-path by construction, including
//! its dead-branch fallbacks), so the Metropolis correction
//! `min(1, ratio · q(i)/q(j))` uses exact proposal odds and the chain is
//! reversible for the same stationary law as the uniform chain — only the
//! *mixing speed* changes.  A fixed `ε = 0.1` uniform mixture keeps the
//! proposal strictly positive everywhere (irreducibility even for items
//! with zero proposal marginal), and per-position proposal probabilities
//! are cached (`q` is a static function of the kernel), so a step costs
//! one tree descent plus the usual `O(k^2 + kK)` minor update — still
//! independent of `M` up to the `log M` descent.
//!
//! Per-step minor cost is `O(k^2 + k K)` via [`IncrementalMinor`]
//! (determinant-lemma ratios + Sherman–Morrison/block-inverse updates),
//! independent of both `M` and `U` — the sampler of choice whenever
//! `Proposal::expected_rejections()` diverges.
//!
//! ## Adaptive burn-in
//!
//! With `adaptive_burn_in` (default on) the chain monitors the lag-1
//! autocorrelation of `log det(L_Y)` over a sliding 64-step window and
//! stops burning in once the trajectory decorrelates (`ρ₁ ≤ 0.2`), bounded
//! below by `burn_in/4` and above by the configured `burn_in` — the knobs
//! keep their meaning as hard bounds.  The decision is a pure function of
//! the chain trajectory, so replay determinism is untouched.
//!
//! ## Reproducibility contract
//!
//! [`Sampler::sample`] restarts the chain from the (lazily computed,
//! kernel-deterministic) greedy MAP seed and burns in, so each sample is a
//! pure function of `(kernel, config, rng state)` — the property the
//! coordinator's batching determinism tests demand.  [`McmcSampler::
//! sample_chain`] amortizes burn-in across a batch by thinning a single
//! chain instead; use it in throughput-sensitive loops where samples may
//! share one request's RNG stream (opt-in on the wire via the `chain`
//! flag).

use crate::learn::map_inference::greedy_map;
use crate::linalg::Matrix;
use crate::ndpp::probability::IncrementalMinor;
use crate::ndpp::proposal::SpectralDpp;
use crate::ndpp::{MarginalKernel, NdppKernel};
use crate::rng::Xoshiro;
use crate::sampler::tree::SampleTree;
use crate::sampler::Sampler;

/// Uniform-mixture weight of the tree proposal: `q(j) = ε/M + (1-ε)
/// q_tree(j)`.  Keeps every item proposable (irreducibility) even when
/// its proposal marginal is numerically zero.
const UNIFORM_MIX: f64 = 0.1;

/// Sliding-window length of the adaptive burn-in autocorrelation estimate.
pub(crate) const BURN_WINDOW: usize = 64;

/// How the up/swap moves draw candidate items.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProposalKind {
    /// Uniform over the catalog — the oracle baseline; mixing scales with
    /// `M`.  Kept behind a config pin for equivalence tests and replay of
    /// pre-tree-proposal deployments.
    Uniform,
    /// Descend the prepared [`SampleTree`] under the proposal-marginal
    /// weight: `O(log M)` per draw, `M`-independent mixing.
    #[default]
    Tree,
}

impl ProposalKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ProposalKind::Uniform => "uniform",
            ProposalKind::Tree => "tree",
        }
    }

    pub fn parse(s: &str) -> Option<ProposalKind> {
        match s {
            "uniform" => Some(ProposalKind::Uniform),
            "tree" => Some(ProposalKind::Tree),
            _ => None,
        }
    }
}

/// Mixing-time knobs for the up-down chains.
#[derive(Debug, Clone, Copy)]
pub struct McmcConfig {
    /// Target sample size `k` (`1 <= k <= min(M, 2K)` for a nonsingular
    /// chain; `0` degenerates to the empty set).  The variable-size chain
    /// uses it as the seed size only.
    pub size: usize,
    /// Upper bound on steps run before the first state is trusted (the
    /// exact count when `adaptive_burn_in` is off).
    pub burn_in: usize,
    /// Steps between recorded states in [`McmcSampler::sample_chain`].
    pub thinning: usize,
    /// Applied moves between full refactorizations of the minor.
    pub refresh_every: usize,
    /// Candidate-item proposal for up/swap moves.
    pub proposal: ProposalKind,
    /// Stop burn-in early once the `log det` trajectory decorrelates
    /// (never before `burn_in / 4` steps, never after `burn_in`).
    pub adaptive_burn_in: bool,
}

impl McmcConfig {
    /// Defaults for a target size on a catalog of `m` items: burn-in scales
    /// with `k log M` (the chain must be able to replace every coordinate
    /// several times), thinning with `k`; tree proposal and adaptive
    /// burn-in on.
    pub fn for_size(size: usize, m: usize) -> McmcConfig {
        let log_m = (m.max(2) as f64).log2().ceil() as usize;
        McmcConfig {
            size,
            burn_in: (30 * size * log_m).max(200),
            thinning: (2 * size).max(1),
            refresh_every: 64,
            proposal: ProposalKind::Tree,
            adaptive_burn_in: true,
        }
    }

    /// Pick the size from the kernel's expected sample size
    /// `E|Y| = tr(K)` (rounded, clamped to `[1, 2K]`) — the fixed-size
    /// sampler then behaves like the unconstrained NDPP conditioned on its
    /// typical cardinality, and the variable-size chain seeds at its
    /// typical cardinality.
    pub fn from_marginal(marginal: &MarginalKernel) -> McmcConfig {
        let expected: f64 = marginal.marginals().iter().sum();
        let size = (expected.round() as usize).clamp(1, marginal.k2().min(marginal.m()));
        McmcConfig::for_size(size, marginal.m())
    }

    /// Convenience: build the marginal kernel and call
    /// [`McmcConfig::from_marginal`] (`O(M K^2)` one-off).
    pub fn for_kernel(kernel: &NdppKernel) -> McmcConfig {
        McmcConfig::from_marginal(&MarginalKernel::build(kernel))
    }
}

/// Proposed / accepted move counters shared by all chain drivers.
///
/// `expected` is the Rao-Blackwellized acceptance mass: the sum over
/// proposed moves of the closed-form Metropolis acceptance probability
/// `min(1, ratio · q(i)/q(j))` *before* the accept/reject coin was
/// flipped (self-loops contribute 0 — they are rejected with
/// probability 1).  `expected / steps` is an unbiased, lower-variance
/// estimate of the same acceptance rate `accepts / steps` estimates,
/// so a realized rate far outside the expected one flags a broken
/// proposal-probability computation in production.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct ChainStats {
    pub steps: u64,
    pub accepts: u64,
    pub expected: f64,
}

/// Candidate-item proposal distribution: either uniform over the catalog
/// or the ε-mixed tree descent.  Owns the descent weight matrix and leaf
/// scratch so repeated draws allocate nothing — the Scratch half of the
/// Prepared/Scratch split (the tree itself is the shared Prepared half).
///
/// `excluded` is the *static* conditioning set (the request basket `J` on
/// conditional chains, empty otherwise): descent probabilities are defined
/// with those items clamped to zero, so `q` never depends on the evolving
/// chain state and per-position probabilities can be cached.  Collisions
/// with the *current* chain state are handled by Metropolis self-loops,
/// not by the proposal.
pub(crate) enum ItemProposal {
    Uniform {
        m: usize,
    },
    Tree {
        weight: Matrix,
        scores: Vec<f64>,
        excluded: Vec<usize>,
        m: usize,
    },
}

impl ItemProposal {
    pub fn uniform(m: usize) -> ItemProposal {
        ItemProposal::Uniform { m }
    }

    /// Tree proposal under an explicit `R x R` weight (conditional chains
    /// pass the conditioned `U diag(λᶜ/(1+λᶜ)) U^T`).  `excluded` must be
    /// sorted ascending.
    pub fn tree(weight: Matrix, excluded: Vec<usize>, m: usize) -> ItemProposal {
        debug_assert!(excluded.windows(2).all(|w| w[0] < w[1]), "excluded must be sorted");
        ItemProposal::Tree { weight, scores: Vec::new(), excluded, m }
    }

    /// Unconditional tree proposal: weight `diag(λ/(1+λ))`, so the item
    /// odds are the proposal-DPP marginals `K̂_jj`.
    pub fn marginal_tree(spectral: &SpectralDpp, m: usize) -> ItemProposal {
        let r = spectral.rank();
        let mut w = Matrix::zeros(r, r);
        for i in 0..r {
            w[(i, i)] = spectral.lambda[i] / (1.0 + spectral.lambda[i]);
        }
        ItemProposal::tree(w, Vec::new(), m)
    }

    pub fn kind(&self) -> ProposalKind {
        match self {
            ItemProposal::Uniform { .. } => ProposalKind::Uniform,
            ItemProposal::Tree { .. } => ProposalKind::Tree,
        }
    }

    /// Draw a candidate item; returns `(j, q(j))` with `q` the exact
    /// probability this proposal assigns to `j`.
    pub fn draw(&mut self, tree: Option<&SampleTree>, rng: &mut Xoshiro) -> (usize, f64) {
        match self {
            ItemProposal::Uniform { m } => (rng.below(*m), 1.0 / *m as f64),
            ItemProposal::Tree { weight, scores, excluded, m } => {
                let tree = tree.expect("tree proposal constructed without a SampleTree");
                let mf = *m as f64;
                if rng.uniform() < UNIFORM_MIX {
                    // uniform leg; the mixture probability still needs the
                    // tree's point mass at the drawn item
                    let j = rng.below(*m);
                    let p = tree.proposal_prob(j, weight, scores, excluded);
                    (j, UNIFORM_MIX / mf + (1.0 - UNIFORM_MIX) * p)
                } else {
                    let (j, p) = tree.propose_item_with(weight, scores, excluded, rng);
                    (j, UNIFORM_MIX / mf + (1.0 - UNIFORM_MIX) * p)
                }
            }
        }
    }

    /// Exact probability the proposal assigns to item `j` (a deterministic
    /// root-to-leaf walk on the tree variant).
    pub fn prob(&mut self, tree: Option<&SampleTree>, j: usize) -> f64 {
        match self {
            ItemProposal::Uniform { m } => 1.0 / *m as f64,
            ItemProposal::Tree { weight, scores, excluded, m } => {
                let tree = tree.expect("tree proposal constructed without a SampleTree");
                let p = tree.proposal_prob(j, weight, scores, excluded);
                UNIFORM_MIX / *m as f64 + (1.0 - UNIFORM_MIX) * p
            }
        }
    }
}

/// One Metropolis swap probe over the free positions `[pinned..]`:
/// uniform position, proposal-drawn candidate, acceptance
/// `min(1, ratio · q(i)/q(j))`.  Returns `(applied, p_accept)` where
/// `p_accept` is the closed-form acceptance probability of the proposed
/// move (0 for self-loops and nonpositive ratios) — the
/// Rao-Blackwellized acceptance telemetry fed into [`ChainStats`].
/// `pos_prob` caches `q` per position and is kept in sync on acceptance.
#[allow(clippy::too_many_arguments)]
pub(crate) fn swap_move(
    minor: &mut IncrementalMinor<'_>,
    pinned: usize,
    prop: &mut ItemProposal,
    tree: Option<&SampleTree>,
    pos_prob: &mut [f64],
    rng: &mut Xoshiro,
) -> (bool, f64) {
    let free = minor.items().len() - pinned;
    let pos = pinned + rng.below(free);
    let (j, qj) = prop.draw(tree, rng);
    if minor.items().contains(&j) {
        return (false, 0.0); // self-loop: proposal keeps Y unchanged
    }
    // swap_if computes the acceptance ratio once and reuses it for the
    // inverse update; the uniform is only drawn for positive ratios.  For
    // the uniform proposal q(i)/q(j) = 1 exactly, reproducing the
    // symmetric-proposal chain bit for bit.
    let qi = pos_prob[pos];
    let mut p_accept = 0.0;
    let (_, accepted) = minor.swap_if(pos, j, |ratio| {
        p_accept = (ratio * (qi / qj)).min(1.0);
        rng.uniform() < ratio * (qi / qj)
    });
    if accepted {
        pos_prob[pos] = qj;
    }
    (accepted, p_accept)
}

/// One variable-size chain move: up with probability 0.4, down with 0.4,
/// swap with 0.2.  Up/down share their move-type probability, so the
/// Metropolis ratios reduce to
///
/// ```text
///   up   (Y -> Y ∪ {j}):  min(1, ratio / ((free+1) · q(j)))
///   down (Y -> Y \ {i}):  min(1, ratio · free · q(i))
/// ```
///
/// with `free` the number of unpinned positions *before* the move.
/// Out-of-range proposals (up at the `cap`, down/swap on an empty free
/// region, candidate already in `Y`) are lazy self-loops — valid
/// Metropolis moves that keep the kernel reversible.  Returns
/// `(applied, p_accept)` like [`swap_move`]: the second element is the
/// closed-form acceptance probability of the proposed move (0 on
/// self-loops), accumulated into [`ChainStats::expected`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn variable_move(
    minor: &mut IncrementalMinor<'_>,
    pinned: usize,
    cap: usize,
    prop: &mut ItemProposal,
    tree: Option<&SampleTree>,
    pos_prob: &mut Vec<f64>,
    rng: &mut Xoshiro,
) -> (bool, f64) {
    let free = minor.items().len() - pinned;
    let u = rng.uniform();
    if u < 0.4 {
        // up-move
        if minor.items().len() >= cap {
            return (false, 0.0);
        }
        let (j, qj) = prop.draw(tree, rng);
        if minor.items().contains(&j) {
            return (false, 0.0);
        }
        let reverse = 1.0 / ((free + 1) as f64 * qj);
        let mut p_accept = 0.0;
        let (_, accepted) = minor.grow_if(j, |ratio| {
            p_accept = (ratio * reverse).min(1.0);
            rng.uniform() < ratio * reverse
        });
        if accepted {
            pos_prob.push(qj);
        }
        (accepted, p_accept)
    } else if u < 0.8 {
        // down-move
        if free == 0 {
            return (false, 0.0);
        }
        let pos = pinned + rng.below(free);
        let qi = pos_prob[pos];
        let mut p_accept = 0.0;
        let (_, accepted) = minor.shrink_if(pos, |ratio| {
            p_accept = (ratio * free as f64 * qi).min(1.0);
            rng.uniform() < ratio * free as f64 * qi
        });
        if accepted {
            pos_prob.remove(pos); // mirror IncrementalMinor's Vec::remove
        }
        (accepted, p_accept)
    } else {
        // swap keeps the size — same move as the fixed-size chain
        if free == 0 {
            return (false, 0.0);
        }
        swap_move(minor, pinned, prop, tree, pos_prob, rng)
    }
}

/// Refill the per-position proposal-probability cache for a fresh minor.
/// Pinned positions get real values too (uniform bookkeeping; they are
/// never read by the move kernels, which only touch `[pinned..]`).
pub(crate) fn fill_pos_probs(
    prop: &mut ItemProposal,
    tree: Option<&SampleTree>,
    items: &[usize],
    pos_prob: &mut Vec<f64>,
) {
    pos_prob.clear();
    for &i in items {
        pos_prob.push(prop.prob(tree, i));
    }
}

/// Online mixedness detector: lag-1 autocorrelation of `log det(L_Y)`
/// over a sliding [`BURN_WINDOW`]-step window, evaluated each time the
/// window refills.  A pure function of the recorded trajectory, so replay
/// determinism is preserved.
#[derive(Debug)]
pub(crate) struct BurnInMeter {
    window: [f64; BURN_WINDOW],
    steps: usize,
}

impl BurnInMeter {
    pub fn new() -> BurnInMeter {
        BurnInMeter { window: [0.0; BURN_WINDOW], steps: 0 }
    }

    /// Record the post-step `log det`; returns true when a full, freshly
    /// rolled-over window looks decorrelated (`ρ₁ ≤ 0.2` with a variance
    /// floor: a frozen trajectory — every proposal rejected — is *not*
    /// mixed, it is stuck, and must keep burning toward the cap).
    pub fn record(&mut self, log_det: f64) -> bool {
        self.window[self.steps % BURN_WINDOW] = log_det;
        self.steps += 1;
        if self.steps < BURN_WINDOW || self.steps % BURN_WINDOW != 0 {
            return false;
        }
        // window is in trajectory order exactly at rollover points
        let n = BURN_WINDOW as f64;
        let mean: f64 = self.window.iter().sum::<f64>() / n;
        let var: f64 = self.window.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        if var <= 1e-12 * (1.0 + mean * mean) {
            return false;
        }
        let mut cov = 0.0;
        for w in self.window.windows(2) {
            cov += (w[0] - mean) * (w[1] - mean);
        }
        cov / (n - 1.0) / var <= 0.2
    }
}

/// Fixed-size up-down Metropolis sampler.  Borrow-based like
/// [`crate::sampler::RejectionSampler`]: the kernel and the (optional)
/// proposal tree are shared, read-only preprocessing; all chain state is
/// local.
pub struct McmcSampler<'a> {
    kernel: &'a NdppKernel,
    config: McmcConfig,
    /// prepared tree for [`ProposalKind::Tree`]; without one the sampler
    /// silently degrades to the uniform proposal (same stationary law)
    tree: Option<&'a SampleTree>,
    prop: Option<ItemProposal>,
    /// greedy MAP warm start, computed lazily on first use
    seed_set: Option<Vec<usize>>,
    /// per-position proposal probabilities of the current chain state
    pos_prob: Vec<f64>,
    stats: ChainStats,
    /// chain steps spent on the most recent sample / batch
    pub last_steps: usize,
    /// burn-in steps actually run on the most recent restart (< config
    /// burn_in when the adaptive meter stopped early)
    pub last_burn_in: usize,
    pub total_samples: u64,
}

impl<'a> McmcSampler<'a> {
    pub fn new(kernel: &'a NdppKernel, config: McmcConfig) -> McmcSampler<'a> {
        assert!(
            config.size <= 2 * kernel.k(),
            "k-NDPP size {} exceeds kernel rank 2K = {}",
            config.size,
            2 * kernel.k()
        );
        assert!(
            config.size <= kernel.m(),
            "k-NDPP size {} exceeds ground-set size M = {}",
            config.size,
            kernel.m()
        );
        McmcSampler {
            kernel,
            config,
            tree: None,
            prop: None,
            seed_set: None,
            pos_prob: Vec::new(),
            stats: ChainStats::default(),
            last_steps: 0,
            last_burn_in: 0,
            total_samples: 0,
        }
    }

    /// Like [`McmcSampler::new`] but with a precomputed warm start (the
    /// coordinator computes [`try_build_seed`] once at model registration
    /// — the Prepared half of the split — so per-request samplers skip the
    /// greedy-MAP run entirely).  The seed must be what
    /// [`try_build_seed`]`(kernel, config.size)` returns; anything else
    /// breaks the reproducibility contract.
    pub fn with_seed(
        kernel: &'a NdppKernel,
        config: McmcConfig,
        seed_items: Vec<usize>,
    ) -> McmcSampler<'a> {
        assert_eq!(
            seed_items.len(),
            config.size,
            "warm start has {} items but the chain targets size {}",
            seed_items.len(),
            config.size
        );
        let mut s = McmcSampler::new(kernel, config);
        s.seed_set = Some(seed_items);
        s
    }

    /// Attach the prepared [`SampleTree`] so [`ProposalKind::Tree`] configs
    /// actually descend it.  The tree is the same read-only structure the
    /// rejection path samples from, built once at registration — attaching
    /// it here rebuilds nothing (pinned by `sampler::tree::build_count`
    /// tests).
    pub fn with_tree(mut self, tree: &'a SampleTree) -> McmcSampler<'a> {
        self.tree = tree.into();
        self.prop = None; // rebuild on next use with the tree attached
        self
    }

    pub fn config(&self) -> McmcConfig {
        self.config
    }

    /// The proposal the chain will actually run with (`Tree` only when a
    /// tree is attached *and* the config asks for it).
    pub fn proposal_kind(&self) -> ProposalKind {
        match (self.config.proposal, self.tree) {
            (ProposalKind::Tree, Some(_)) => ProposalKind::Tree,
            _ => ProposalKind::Uniform,
        }
    }

    /// Fraction of proposed moves accepted so far (diagnostic: healthy
    /// chains sit well above a few percent).
    pub fn acceptance_rate(&self) -> f64 {
        if self.stats.steps == 0 {
            0.0
        } else {
            self.stats.accepts as f64 / self.stats.steps as f64
        }
    }

    /// `(proposed, accepted, expected_accept_mass)` move totals since
    /// construction — the third element is the Rao-Blackwellized sum of
    /// closed-form acceptance probabilities (see [`ChainStats`]).
    pub fn chain_stats(&self) -> (u64, u64, f64) {
        (self.stats.steps, self.stats.accepts, self.stats.expected)
    }

    /// The greedy-MAP warm start (lazy; deterministic in the kernel).  The
    /// greedy run can stop short of `k` when conditioning goes singular, in
    /// which case the seed is topped up with the lowest-index items that
    /// keep `det(L_Y) > 0`.
    pub fn seed_items(&mut self) -> &[usize] {
        if self.seed_set.is_none() {
            self.seed_set = Some(build_seed(self.kernel, self.config.size));
        }
        self.seed_set.as_deref().expect("just initialized")
    }

    fn proposal(&mut self) -> &mut ItemProposal {
        if self.prop.is_none() {
            self.prop = Some(match (self.config.proposal, self.tree) {
                (ProposalKind::Tree, Some(t)) => {
                    ItemProposal::marginal_tree(t.spectral(), self.kernel.m())
                }
                _ => ItemProposal::uniform(self.kernel.m()),
            });
        }
        self.prop.as_mut().expect("just initialized")
    }

    /// One proposed up-down move; returns whether it was accepted.
    fn step(&mut self, minor: &mut IncrementalMinor<'_>, rng: &mut Xoshiro) -> bool {
        self.proposal();
        self.stats.steps += 1;
        let (accepted, p_accept) = swap_move(
            minor,
            0,
            self.prop.as_mut().expect("proposal ready"),
            self.tree,
            &mut self.pos_prob,
            rng,
        );
        self.stats.expected += p_accept;
        if accepted {
            self.stats.accepts += 1;
        }
        accepted
    }

    /// Fresh minor at the greedy seed.  The seed construction only admits
    /// positive-determinant sets, so failure here means the kernel admits
    /// no usable size-k state at all — a configuration error worth
    /// panicking over (the coordinator's worker pool isolates panics, so a
    /// degenerate model cannot take the service down).
    fn fresh_minor(&mut self) -> IncrementalMinor<'a> {
        let seed = self.seed_items().to_vec();
        let mut minor = IncrementalMinor::new(self.kernel, seed)
            .expect("greedy MAP seed has positive determinant");
        minor.refresh_every = self.config.refresh_every.max(1);
        self.proposal();
        fill_pos_probs(
            self.prop.as_mut().expect("proposal ready"),
            self.tree,
            minor.items(),
            &mut self.pos_prob,
        );
        minor
    }

    /// One step plus drift recovery: if a refactorization inside the step
    /// found the state numerically singular, restart from the greedy seed
    /// (still a pure function of the rng stream, so determinism holds).
    fn step_or_reseed(&mut self, minor: &mut IncrementalMinor<'a>, rng: &mut Xoshiro) {
        self.step(minor, rng);
        if !minor.is_healthy() {
            *minor = self.fresh_minor();
        }
    }

    fn start_chain(&mut self, rng: &mut Xoshiro) -> IncrementalMinor<'a> {
        let mut minor = self.fresh_minor();
        let cap = self.config.burn_in;
        if !self.config.adaptive_burn_in {
            for _ in 0..cap {
                self.step_or_reseed(&mut minor, rng);
            }
            self.last_burn_in = cap;
            return minor;
        }
        let floor = (cap / 4).max(BURN_WINDOW).min(cap);
        let mut meter = BurnInMeter::new();
        let mut steps = 0;
        while steps < cap {
            self.step_or_reseed(&mut minor, rng);
            steps += 1;
            if meter.record(minor.log_det()) && steps >= floor {
                break;
            }
        }
        self.last_burn_in = steps;
        minor
    }

    /// Draw `n` states from a single chain: one burn-in, then `thinning`
    /// steps between successive records.  Cheaper than `n` independent
    /// [`Sampler::sample`] calls by a factor of roughly
    /// `burn_in / thinning`; successive states are correlated at lags
    /// shorter than the chain's mixing time.
    pub fn sample_chain(&mut self, n: usize, rng: &mut Xoshiro) -> Vec<Vec<usize>> {
        if self.config.size == 0 || n == 0 {
            return vec![Vec::new(); n];
        }
        let mut minor = self.start_chain(rng);
        let mut steps = self.last_burn_in;
        let mut out = Vec::with_capacity(n);
        for idx in 0..n {
            if idx > 0 {
                for _ in 0..self.config.thinning {
                    self.step_or_reseed(&mut minor, rng);
                }
                steps += self.config.thinning;
            }
            let mut y = minor.items().to_vec();
            y.sort_unstable();
            out.push(y);
        }
        self.last_steps = steps;
        self.total_samples += n as u64;
        out
    }
}

impl Sampler for McmcSampler<'_> {
    /// Restart the chain from the greedy seed and burn in — each call is a
    /// pure function of `(kernel, rng state)`, independent of prior calls.
    fn sample(&mut self, rng: &mut Xoshiro) -> Vec<usize> {
        if self.config.size == 0 {
            return Vec::new();
        }
        let minor = self.start_chain(rng);
        self.last_steps = self.last_burn_in;
        self.total_samples += 1;
        let mut y = minor.items().to_vec();
        y.sort_unstable();
        y
    }

    fn name(&self) -> &'static str {
        "mcmc-updown"
    }
}

/// Variable-size up/down/swap Metropolis sampler for the unconstrained
/// target `Pr(Y) ∝ det(L_Y)` — the full NDPP law, cardinality included,
/// for kernels where rejection's `U` diverges and no fast exact sampler
/// exists.  Seeds at the kernel's typical cardinality (`config.size`) and
/// walks sizes `0 ..= min(M, 2K)`.
pub struct VariableMcmcSampler<'a> {
    kernel: &'a NdppKernel,
    config: McmcConfig,
    tree: Option<&'a SampleTree>,
    prop: Option<ItemProposal>,
    seed_set: Option<Vec<usize>>,
    pos_prob: Vec<f64>,
    stats: ChainStats,
    /// hard size ceiling `min(M, 2K)`: beyond the kernel rank every minor
    /// is singular, so up-moves there are wasted probes
    cap: usize,
    pub last_steps: usize,
    pub last_burn_in: usize,
    pub total_samples: u64,
}

impl<'a> VariableMcmcSampler<'a> {
    pub fn new(kernel: &'a NdppKernel, config: McmcConfig) -> VariableMcmcSampler<'a> {
        let cap = kernel.m().min(2 * kernel.k());
        assert!(
            config.size <= cap,
            "seed size {} exceeds the chain's size ceiling min(M, 2K) = {cap}",
            config.size
        );
        VariableMcmcSampler {
            kernel,
            config,
            tree: None,
            prop: None,
            seed_set: None,
            pos_prob: Vec::new(),
            stats: ChainStats::default(),
            cap,
            last_steps: 0,
            last_burn_in: 0,
            total_samples: 0,
        }
    }

    /// Attach the prepared [`SampleTree`] (see [`McmcSampler::with_tree`]).
    pub fn with_tree(mut self, tree: &'a SampleTree) -> VariableMcmcSampler<'a> {
        self.tree = tree.into();
        self.prop = None;
        self
    }

    /// Precomputed warm start, as [`McmcSampler::with_seed`].
    pub fn with_seed(
        kernel: &'a NdppKernel,
        config: McmcConfig,
        seed_items: Vec<usize>,
    ) -> VariableMcmcSampler<'a> {
        assert_eq!(
            seed_items.len(),
            config.size,
            "warm start has {} items but the chain seeds at size {}",
            seed_items.len(),
            config.size
        );
        let mut s = VariableMcmcSampler::new(kernel, config);
        s.seed_set = Some(seed_items);
        s
    }

    pub fn config(&self) -> McmcConfig {
        self.config
    }

    pub fn proposal_kind(&self) -> ProposalKind {
        match (self.config.proposal, self.tree) {
            (ProposalKind::Tree, Some(_)) => ProposalKind::Tree,
            _ => ProposalKind::Uniform,
        }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.stats.steps == 0 {
            0.0
        } else {
            self.stats.accepts as f64 / self.stats.steps as f64
        }
    }

    /// `(proposed, accepted, expected_accept_mass)` as
    /// [`McmcSampler::chain_stats`].
    pub fn chain_stats(&self) -> (u64, u64, f64) {
        (self.stats.steps, self.stats.accepts, self.stats.expected)
    }

    fn proposal(&mut self) -> &mut ItemProposal {
        if self.prop.is_none() {
            self.prop = Some(match (self.config.proposal, self.tree) {
                (ProposalKind::Tree, Some(t)) => {
                    ItemProposal::marginal_tree(t.spectral(), self.kernel.m())
                }
                _ => ItemProposal::uniform(self.kernel.m()),
            });
        }
        self.prop.as_mut().expect("just initialized")
    }

    fn seed_items(&mut self) -> &[usize] {
        if self.seed_set.is_none() {
            self.seed_set = Some(build_seed(self.kernel, self.config.size));
        }
        self.seed_set.as_deref().expect("just initialized")
    }

    fn fresh_minor(&mut self) -> IncrementalMinor<'a> {
        let seed = self.seed_items().to_vec();
        let mut minor = IncrementalMinor::new(self.kernel, seed)
            .expect("greedy MAP seed has positive determinant");
        minor.refresh_every = self.config.refresh_every.max(1);
        self.proposal();
        fill_pos_probs(
            self.prop.as_mut().expect("proposal ready"),
            self.tree,
            minor.items(),
            &mut self.pos_prob,
        );
        minor
    }

    fn step_or_reseed(&mut self, minor: &mut IncrementalMinor<'a>, rng: &mut Xoshiro) {
        self.proposal();
        self.stats.steps += 1;
        let (accepted, p_accept) = variable_move(
            minor,
            0,
            self.cap,
            self.prop.as_mut().expect("proposal ready"),
            self.tree,
            &mut self.pos_prob,
            rng,
        );
        self.stats.expected += p_accept;
        if accepted {
            self.stats.accepts += 1;
        }
        if !minor.is_healthy() {
            *minor = self.fresh_minor();
        }
    }

    fn start_chain(&mut self, rng: &mut Xoshiro) -> IncrementalMinor<'a> {
        let mut minor = self.fresh_minor();
        let cap = self.config.burn_in;
        if !self.config.adaptive_burn_in {
            for _ in 0..cap {
                self.step_or_reseed(&mut minor, rng);
            }
            self.last_burn_in = cap;
            return minor;
        }
        let floor = (cap / 4).max(BURN_WINDOW).min(cap);
        let mut meter = BurnInMeter::new();
        let mut steps = 0;
        while steps < cap {
            self.step_or_reseed(&mut minor, rng);
            steps += 1;
            if meter.record(minor.log_det()) && steps >= floor {
                break;
            }
        }
        self.last_burn_in = steps;
        minor
    }

    /// Thinned single-chain batch, as [`McmcSampler::sample_chain`].
    pub fn sample_chain(&mut self, n: usize, rng: &mut Xoshiro) -> Vec<Vec<usize>> {
        if n == 0 {
            return Vec::new();
        }
        let mut minor = self.start_chain(rng);
        let mut steps = self.last_burn_in;
        let mut out = Vec::with_capacity(n);
        for idx in 0..n {
            if idx > 0 {
                for _ in 0..self.config.thinning {
                    self.step_or_reseed(&mut minor, rng);
                }
                steps += self.config.thinning;
            }
            let mut y = minor.items().to_vec();
            y.sort_unstable();
            out.push(y);
        }
        self.last_steps = steps;
        self.total_samples += n as u64;
        out
    }
}

impl Sampler for VariableMcmcSampler<'_> {
    fn sample(&mut self, rng: &mut Xoshiro) -> Vec<usize> {
        let minor = self.start_chain(rng);
        self.last_steps = self.last_burn_in;
        self.total_samples += 1;
        let mut y = minor.items().to_vec();
        y.sort_unstable();
        y
    }

    fn name(&self) -> &'static str {
        "mcmc-updown-var"
    }
}

/// Greedy MAP seed of exactly `size` items (see
/// [`McmcSampler::seed_items`]).
fn build_seed(kernel: &NdppKernel, size: usize) -> Vec<usize> {
    try_build_seed(kernel, size).unwrap_or_else(|| {
        panic!("no size-{size} subset with positive probability found (kernel rank too low?)")
    })
}

/// Fallible greedy-MAP warm start: `None` when the kernel admits no
/// size-`size` subset with positive determinant (numerically
/// rank-deficient kernels).  Deterministic in the kernel — the
/// coordinator runs this once at registration and hands the result to
/// every [`McmcSampler::with_seed`].
pub fn try_build_seed(kernel: &NdppKernel, size: usize) -> Option<Vec<usize>> {
    let mut items = greedy_map(kernel, size, 0.0).items;
    items.truncate(size);
    if items.len() < size {
        for j in 0..kernel.m() {
            if items.len() == size {
                break;
            }
            if items.contains(&j) {
                continue;
            }
            items.push(j);
            if IncrementalMinor::new(kernel, items.clone()).is_none() {
                items.pop();
            }
        }
    }
    if items.len() == size {
        Some(items)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::probability::{det_l_y, enumerate_probs};
    use crate::ndpp::Proposal;
    use crate::sampler::TreeConfig;
    use crate::util::testing::{
        chi_square_gof, conditioned_on_size, empirical, empirical_from, tv,
    };

    fn tree_for(kernel: &NdppKernel) -> SampleTree {
        SampleTree::build(&Proposal::build(kernel).spectral(), TreeConfig { leaf_size: 4 })
    }

    /// Module-level statistical sanity check, deliberately smaller than
    /// the exhaustive cross-sampler suite in `tests/conformance.rs` (which
    /// holds both kernel classes to TV + chi-square at 30k draws) so the
    /// slow restart-mode sampling is not paid twice per CI run.
    #[test]
    fn conformance_smoke_on_ondpp_kernel() {
        let mut rng = Xoshiro::seeded(61);
        let kernel = NdppKernel::random_ondpp(7, 2, &mut rng);
        let size = 3;
        let want = conditioned_on_size(&enumerate_probs(&kernel), size);
        let mut s = McmcSampler::new(&kernel, McmcConfig::for_size(size, 7));
        let n = 8_000;
        let got = empirical(&mut s, 7, n, &mut rng);
        let d = tv(&got, &want);
        assert!(d < 0.06, "tv={d}");
        let cs = chi_square_gof(&got, &want, n);
        assert!(cs.passes(), "chi2 stat={} crit={} df={}", cs.stat, cs.crit_999, cs.df);
        assert!(s.acceptance_rate() > 0.02, "acceptance {}", s.acceptance_rate());
    }

    #[test]
    fn tree_proposal_holds_the_same_law() {
        // the tentpole invariant: switching the proposal must not move the
        // stationary distribution, only the mixing speed
        let mut rng = Xoshiro::seeded(62);
        let kernel = NdppKernel::random_ondpp(7, 2, &mut rng);
        let size = 3;
        let tree = tree_for(&kernel);
        let want = conditioned_on_size(&enumerate_probs(&kernel), size);
        let mut s = McmcSampler::new(&kernel, McmcConfig::for_size(size, 7)).with_tree(&tree);
        assert_eq!(s.proposal_kind(), ProposalKind::Tree);
        let n = 8_000;
        let got = empirical(&mut s, 7, n, &mut rng);
        let d = tv(&got, &want);
        assert!(d < 0.06, "tv={d}");
        let cs = chi_square_gof(&got, &want, n);
        assert!(cs.passes(), "chi2 stat={} crit={} df={}", cs.stat, cs.crit_999, cs.df);
        assert!(s.acceptance_rate() > 0.02, "acceptance {}", s.acceptance_rate());
    }

    #[test]
    fn variable_chain_matches_unconstrained_law() {
        let mut rng = Xoshiro::seeded(71);
        let kernel = NdppKernel::random_ondpp(6, 2, &mut rng);
        let tree = tree_for(&kernel);
        let want = enumerate_probs(&kernel);
        let mut s =
            VariableMcmcSampler::new(&kernel, McmcConfig::for_kernel(&kernel)).with_tree(&tree);
        let n = 12_000;
        let got = empirical(&mut s, 6, n, &mut rng);
        let d = tv(&got, &want);
        assert!(d < 0.06, "tv={d}");
        let cs = chi_square_gof(&got, &want, n);
        assert!(cs.passes(), "chi2 stat={} crit={} df={}", cs.stat, cs.crit_999, cs.df);
    }

    #[test]
    fn chain_mode_matches_restart_distribution() {
        let mut rng = Xoshiro::seeded(63);
        let kernel = NdppKernel::random_ondpp(6, 2, &mut rng);
        let size = 2;
        let want = conditioned_on_size(&enumerate_probs(&kernel), size);
        let mut s = McmcSampler::new(&kernel, McmcConfig::for_size(size, 6));
        let n = 30_000;
        let mut chain = s.sample_chain(n, &mut rng).into_iter();
        let freq = empirical_from(6, n, &mut rng, |_| chain.next().expect("n chain states"));
        // thinned-chain samples are correlated, so hold only the TV bound
        let d = tv(&freq, &want);
        assert!(d < 0.04, "tv={d}");
    }

    #[test]
    fn adaptive_burn_in_stays_within_config_bounds() {
        let mut rng = Xoshiro::seeded(72);
        let kernel = NdppKernel::random_ondpp(20, 3, &mut rng);
        let tree = tree_for(&kernel);
        let cfg = McmcConfig::for_size(3, 20);
        assert!(cfg.adaptive_burn_in);
        let mut s = McmcSampler::new(&kernel, cfg).with_tree(&tree);
        let _ = s.sample(&mut rng);
        assert!(s.last_burn_in <= cfg.burn_in);
        assert!(s.last_burn_in >= (cfg.burn_in / 4).max(BURN_WINDOW).min(cfg.burn_in));
        // pinned off, the knob is exact
        let mut fixed_cfg = cfg;
        fixed_cfg.adaptive_burn_in = false;
        let mut s2 = McmcSampler::new(&kernel, fixed_cfg).with_tree(&tree);
        let _ = s2.sample(&mut rng);
        assert_eq!(s2.last_burn_in, cfg.burn_in);
    }

    #[test]
    fn samples_are_valid_k_subsets() {
        let mut rng = Xoshiro::seeded(64);
        let kernel = NdppKernel::random_ondpp(40, 4, &mut rng);
        let tree = tree_for(&kernel);
        let mut s = McmcSampler::new(&kernel, McmcConfig::for_size(4, 40)).with_tree(&tree);
        for _ in 0..10 {
            let y = s.sample(&mut rng);
            assert_eq!(y.len(), 4);
            assert!(y.windows(2).all(|w| w[0] < w[1]), "sorted distinct: {y:?}");
            assert!(y.iter().all(|&i| i < 40));
            assert!(det_l_y(&kernel, &y) > 0.0);
        }
    }

    #[test]
    fn variable_samples_are_valid_subsets() {
        let mut rng = Xoshiro::seeded(73);
        let kernel = NdppKernel::random_ondpp(30, 4, &mut rng);
        let tree = tree_for(&kernel);
        let mut s =
            VariableMcmcSampler::new(&kernel, McmcConfig::for_kernel(&kernel)).with_tree(&tree);
        let mut sizes = std::collections::BTreeSet::new();
        for _ in 0..30 {
            let y = s.sample(&mut rng);
            assert!(y.len() <= 8, "above the rank ceiling: {y:?}");
            assert!(y.windows(2).all(|w| w[0] < w[1]), "sorted distinct: {y:?}");
            assert!(y.iter().all(|&i| i < 30));
            if !y.is_empty() {
                assert!(det_l_y(&kernel, &y) > 0.0);
            }
            sizes.insert(y.len());
        }
        assert!(sizes.len() > 1, "variable chain never changed size: {sizes:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng_k = Xoshiro::seeded(65);
        let kernel = NdppKernel::random_ondpp(30, 4, &mut rng_k);
        let tree = tree_for(&kernel);
        let cfg = McmcConfig::for_size(3, 30);
        let mut s1 = McmcSampler::new(&kernel, cfg).with_tree(&tree);
        let mut s2 = McmcSampler::new(&kernel, cfg).with_tree(&tree);
        let mut r1 = Xoshiro::seeded(9);
        let mut r2 = Xoshiro::seeded(9);
        for _ in 0..5 {
            assert_eq!(s1.sample(&mut r1), s2.sample(&mut r2));
        }
        // restart semantics: a fresh sampler at the same rng point agrees
        let mut s3 = McmcSampler::new(&kernel, cfg).with_tree(&tree);
        let mut r3 = Xoshiro::seeded(9);
        let first = s3.sample(&mut r3);
        let mut s4 = McmcSampler::new(&kernel, cfg).with_tree(&tree);
        let mut r4 = Xoshiro::seeded(9);
        assert_eq!(first, s4.sample(&mut r4));
        // and the variable chain likewise
        let vcfg = McmcConfig::for_kernel(&kernel);
        let mut v1 = VariableMcmcSampler::new(&kernel, vcfg).with_tree(&tree);
        let mut v2 = VariableMcmcSampler::new(&kernel, vcfg).with_tree(&tree);
        let mut r5 = Xoshiro::seeded(9);
        let mut r6 = Xoshiro::seeded(9);
        for _ in 0..5 {
            assert_eq!(v1.sample(&mut r5), v2.sample(&mut r6));
        }
    }

    #[test]
    fn precomputed_seed_matches_lazy_path() {
        // with_seed (registration-time greedy MAP) and new (lazy greedy
        // MAP) must be byte-identical per rng stream
        let mut rng_k = Xoshiro::seeded(70);
        let kernel = NdppKernel::random_ondpp(30, 4, &mut rng_k);
        let tree = tree_for(&kernel);
        let cfg = McmcConfig::for_size(3, 30);
        let seed = try_build_seed(&kernel, 3).expect("healthy kernel has a seed");
        let mut lazy = McmcSampler::new(&kernel, cfg).with_tree(&tree);
        let mut warm = McmcSampler::with_seed(&kernel, cfg, seed).with_tree(&tree);
        let mut r1 = Xoshiro::seeded(5);
        let mut r2 = Xoshiro::seeded(5);
        for _ in 0..3 {
            assert_eq!(lazy.sample(&mut r1), warm.sample(&mut r2));
        }
    }

    #[test]
    fn uniform_pin_without_tree_matches_tree_bearing_uniform() {
        // the config pin, not tree availability, selects the proposal:
        // a Uniform-pinned sampler ignores an attached tree entirely
        let mut rng_k = Xoshiro::seeded(74);
        let kernel = NdppKernel::random_ondpp(20, 3, &mut rng_k);
        let tree = tree_for(&kernel);
        let mut cfg = McmcConfig::for_size(3, 20);
        cfg.proposal = ProposalKind::Uniform;
        let mut bare = McmcSampler::new(&kernel, cfg);
        let mut pinned = McmcSampler::new(&kernel, cfg).with_tree(&tree);
        assert_eq!(bare.proposal_kind(), ProposalKind::Uniform);
        assert_eq!(pinned.proposal_kind(), ProposalKind::Uniform);
        let mut r1 = Xoshiro::seeded(6);
        let mut r2 = Xoshiro::seeded(6);
        for _ in 0..4 {
            assert_eq!(bare.sample(&mut r1), pinned.sample(&mut r2));
        }
    }

    #[test]
    fn default_size_tracks_expected_cardinality() {
        let mut rng = Xoshiro::seeded(66);
        let kernel = NdppKernel::random_ondpp(60, 4, &mut rng);
        let cfg = McmcConfig::for_kernel(&kernel);
        let mk = MarginalKernel::build(&kernel);
        let expected: f64 = mk.marginals().iter().sum();
        assert_eq!(cfg.size, (expected.round() as usize).clamp(1, 8));
        assert!(cfg.burn_in >= 200);
        assert!(cfg.thinning >= 1);
        assert_eq!(cfg.proposal, ProposalKind::Tree);
        assert!(cfg.adaptive_burn_in);
    }

    #[test]
    fn survives_kernel_with_diverging_rejection_rate() {
        // the motivating regime: rejection sampling needs thousands of
        // proposals per sample, the chain's per-step cost doesn't care
        let mut rng = Xoshiro::seeded(67);
        let kernel = crate::bench::experiments::nonorthogonal_kernel(64, 24, 1.0, &mut rng);
        let u = crate::ndpp::Proposal::build(&kernel).expected_rejections();
        assert!(u > 100.0, "construction too tame: U={u}");
        let tree = tree_for(&kernel);
        let mut s = McmcSampler::new(&kernel, McmcConfig::for_size(10, 64)).with_tree(&tree);
        for _ in 0..3 {
            let y = s.sample(&mut rng);
            assert_eq!(y.len(), 10);
            assert!(det_l_y(&kernel, &y) > 0.0);
        }
        assert!(s.acceptance_rate() > 0.0);
    }

    #[test]
    fn size_zero_returns_empty_sets() {
        let mut rng = Xoshiro::seeded(68);
        let kernel = NdppKernel::random_ondpp(12, 2, &mut rng);
        let mut s = McmcSampler::new(
            &kernel,
            McmcConfig {
                size: 0,
                burn_in: 10,
                thinning: 1,
                refresh_every: 8,
                proposal: ProposalKind::Tree,
                adaptive_burn_in: true,
            },
        );
        assert!(s.sample(&mut rng).is_empty());
        assert_eq!(s.sample_chain(3, &mut rng), vec![Vec::<usize>::new(); 3]);
    }

    #[test]
    #[should_panic(expected = "exceeds kernel rank")]
    fn size_beyond_rank_is_rejected() {
        let mut rng = Xoshiro::seeded(69);
        let kernel = NdppKernel::random_ondpp(12, 2, &mut rng);
        let _ = McmcSampler::new(&kernel, McmcConfig::for_size(5, 12));
    }
}
