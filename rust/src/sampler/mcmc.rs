//! Fixed-size (k-NDPP) MCMC up-down sampler.
//!
//! The rejection sampler's cost is governed by `U = det(L̂+I)/det(L+I)`,
//! which explodes (`~2^{K/2}`) once the ONDPP orthogonality/regularization
//! that Theorem 2 relies on is relaxed — exactly the kernels the follow-up
//! paper *Scalable MCMC Sampling for Nonsymmetric Determinantal Point
//! Processes* (Han, Gartrell, Dohmatob, Karbasi 2022) targets with a
//! low-rank up-down random walk.  This module implements that walk for the
//! fixed-size target
//!
//! ```text
//!   Pr(Y) ∝ det(L_Y) · 1[|Y| = k]
//! ```
//!
//! as a Metropolis chain over k-subsets: propose replacing a uniformly
//! chosen position of `Y` with a uniformly chosen catalog item and accept
//! with probability `min(1, det(L_{Y'})/det(L_Y))`.  The proposal is
//! symmetric, so the chain is reversible with the k-NDPP as its stationary
//! distribution; every principal minor of `L = V V^T + B C B^T` is
//! nonnegative, so the acceptance ratio is well defined.
//!
//! Per-step cost is `O(k^2 + k K)` via the incrementally maintained minor
//! ([`IncrementalMinor`]: determinant-lemma ratio + two Sherman–Morrison
//! inverse updates), independent of both `M` and `U` — the sampler of
//! choice whenever `Proposal::expected_rejections()` diverges.
//!
//! ## Reproducibility contract
//!
//! [`Sampler::sample`] restarts the chain from the (lazily computed,
//! kernel-deterministic) greedy MAP seed and runs `burn_in` steps, so each
//! sample is a pure function of `(kernel, rng state)` — the property the
//! coordinator's batching determinism tests demand.  [`McmcSampler::
//! sample_chain`] amortizes burn-in across a batch by thinning a single
//! chain instead; use it in throughput-sensitive loops where samples may
//! share one request's RNG stream.

use crate::learn::map_inference::greedy_map;
use crate::ndpp::probability::IncrementalMinor;
use crate::ndpp::{MarginalKernel, NdppKernel};
use crate::rng::Xoshiro;
use crate::sampler::Sampler;

/// Mixing-time knobs for the up-down chain.
#[derive(Debug, Clone, Copy)]
pub struct McmcConfig {
    /// Target sample size `k` (`1 <= k <= min(M, 2K)` for a nonsingular
    /// chain; `0` degenerates to the empty set).
    pub size: usize,
    /// Steps run before the first state is trusted.
    pub burn_in: usize,
    /// Steps between recorded states in [`McmcSampler::sample_chain`].
    pub thinning: usize,
    /// Applied swaps between full refactorizations of the minor.
    pub refresh_every: usize,
}

impl McmcConfig {
    /// Defaults for a target size on a catalog of `m` items: burn-in scales
    /// with `k log M` (the chain must be able to replace every coordinate
    /// several times), thinning with `k`.
    pub fn for_size(size: usize, m: usize) -> McmcConfig {
        let log_m = (m.max(2) as f64).log2().ceil() as usize;
        McmcConfig {
            size,
            burn_in: (30 * size * log_m).max(200),
            thinning: (2 * size).max(1),
            refresh_every: 64,
        }
    }

    /// Pick the size from the kernel's expected sample size
    /// `E|Y| = tr(K)` (rounded, clamped to `[1, 2K]`) — the fixed-size
    /// sampler then behaves like the unconstrained NDPP conditioned on its
    /// typical cardinality.
    pub fn from_marginal(marginal: &MarginalKernel) -> McmcConfig {
        let expected: f64 = marginal.marginals().iter().sum();
        let size = (expected.round() as usize).clamp(1, marginal.k2().min(marginal.m()));
        McmcConfig::for_size(size, marginal.m())
    }

    /// Convenience: build the marginal kernel and call
    /// [`McmcConfig::from_marginal`] (`O(M K^2)` one-off).
    pub fn for_kernel(kernel: &NdppKernel) -> McmcConfig {
        McmcConfig::from_marginal(&MarginalKernel::build(kernel))
    }
}

/// Fixed-size up-down Metropolis sampler.  Borrow-based like
/// [`crate::sampler::RejectionSampler`]: the kernel is shared, read-only
/// preprocessing; all chain state is local.
pub struct McmcSampler<'a> {
    kernel: &'a NdppKernel,
    config: McmcConfig,
    /// greedy MAP warm start, computed lazily on first use
    seed_set: Option<Vec<usize>>,
    /// chain steps spent on the most recent sample / batch
    pub last_steps: usize,
    /// running totals for acceptance-rate reporting
    pub total_steps: u64,
    pub total_accepts: u64,
    pub total_samples: u64,
}

impl<'a> McmcSampler<'a> {
    pub fn new(kernel: &'a NdppKernel, config: McmcConfig) -> McmcSampler<'a> {
        assert!(
            config.size <= 2 * kernel.k(),
            "k-NDPP size {} exceeds kernel rank 2K = {}",
            config.size,
            2 * kernel.k()
        );
        assert!(
            config.size <= kernel.m(),
            "k-NDPP size {} exceeds ground-set size M = {}",
            config.size,
            kernel.m()
        );
        McmcSampler {
            kernel,
            config,
            seed_set: None,
            last_steps: 0,
            total_steps: 0,
            total_accepts: 0,
            total_samples: 0,
        }
    }

    /// Like [`McmcSampler::new`] but with a precomputed warm start (the
    /// coordinator computes [`try_build_seed`] once at model registration
    /// — the Prepared half of the split — so per-request samplers skip the
    /// greedy-MAP run entirely).  The seed must be what
    /// [`try_build_seed`]`(kernel, config.size)` returns; anything else
    /// breaks the reproducibility contract.
    pub fn with_seed(
        kernel: &'a NdppKernel,
        config: McmcConfig,
        seed_items: Vec<usize>,
    ) -> McmcSampler<'a> {
        assert_eq!(
            seed_items.len(),
            config.size,
            "warm start has {} items but the chain targets size {}",
            seed_items.len(),
            config.size
        );
        let mut s = McmcSampler::new(kernel, config);
        s.seed_set = Some(seed_items);
        s
    }

    pub fn config(&self) -> McmcConfig {
        self.config
    }

    /// Fraction of proposed swaps accepted so far (diagnostic: healthy
    /// chains sit well above a few percent).
    pub fn acceptance_rate(&self) -> f64 {
        if self.total_steps == 0 {
            0.0
        } else {
            self.total_accepts as f64 / self.total_steps as f64
        }
    }

    /// The greedy-MAP warm start (lazy; deterministic in the kernel).  The
    /// greedy run can stop short of `k` when conditioning goes singular, in
    /// which case the seed is topped up with the lowest-index items that
    /// keep `det(L_Y) > 0`.
    pub fn seed_items(&mut self) -> &[usize] {
        if self.seed_set.is_none() {
            self.seed_set = Some(build_seed(self.kernel, self.config.size));
        }
        self.seed_set.as_deref().expect("just initialized")
    }

    /// One proposed up-down move; returns whether it was accepted.
    fn step(&mut self, minor: &mut IncrementalMinor<'_>, rng: &mut Xoshiro) -> bool {
        let pos = rng.below(self.config.size);
        let j = rng.below(self.kernel.m());
        self.total_steps += 1;
        if minor.items().contains(&j) {
            return false; // self-loop: proposal keeps Y unchanged
        }
        // swap_if computes the acceptance ratio once and reuses it for the
        // inverse update; the uniform is only drawn for positive ratios
        let (_, accepted) = minor.swap_if(pos, j, |ratio| rng.uniform() < ratio);
        if accepted {
            self.total_accepts += 1;
        }
        accepted
    }

    /// Fresh minor at the greedy seed.  The seed construction only admits
    /// positive-determinant sets, so failure here means the kernel admits
    /// no usable size-k state at all — a configuration error worth
    /// panicking over (the coordinator's worker pool isolates panics, so a
    /// degenerate model cannot take the service down).
    fn fresh_minor(&mut self) -> IncrementalMinor<'a> {
        let seed = self.seed_items().to_vec();
        let mut minor = IncrementalMinor::new(self.kernel, seed)
            .expect("greedy MAP seed has positive determinant");
        minor.refresh_every = self.config.refresh_every.max(1);
        minor
    }

    /// One step plus drift recovery: if a refactorization inside the step
    /// found the state numerically singular, restart from the greedy seed
    /// (still a pure function of the rng stream, so determinism holds).
    fn step_or_reseed(&mut self, minor: &mut IncrementalMinor<'a>, rng: &mut Xoshiro) {
        self.step(minor, rng);
        if !minor.is_healthy() {
            *minor = self.fresh_minor();
        }
    }

    fn start_chain(&mut self, rng: &mut Xoshiro) -> IncrementalMinor<'a> {
        let mut minor = self.fresh_minor();
        for _ in 0..self.config.burn_in {
            self.step_or_reseed(&mut minor, rng);
        }
        minor
    }

    /// Draw `n` states from a single chain: one burn-in, then `thinning`
    /// steps between successive records.  Cheaper than `n` independent
    /// [`Sampler::sample`] calls by a factor of roughly
    /// `burn_in / thinning`; successive states are correlated at lags
    /// shorter than the chain's mixing time.
    pub fn sample_chain(&mut self, n: usize, rng: &mut Xoshiro) -> Vec<Vec<usize>> {
        if self.config.size == 0 || n == 0 {
            return vec![Vec::new(); n];
        }
        let mut minor = self.start_chain(rng);
        let mut steps = self.config.burn_in;
        let mut out = Vec::with_capacity(n);
        for idx in 0..n {
            if idx > 0 {
                for _ in 0..self.config.thinning {
                    self.step_or_reseed(&mut minor, rng);
                }
                steps += self.config.thinning;
            }
            let mut y = minor.items().to_vec();
            y.sort_unstable();
            out.push(y);
        }
        self.last_steps = steps;
        self.total_samples += n as u64;
        out
    }
}

impl Sampler for McmcSampler<'_> {
    /// Restart the chain from the greedy seed and burn in — each call is a
    /// pure function of `(kernel, rng state)`, independent of prior calls.
    fn sample(&mut self, rng: &mut Xoshiro) -> Vec<usize> {
        if self.config.size == 0 {
            return Vec::new();
        }
        let minor = self.start_chain(rng);
        self.last_steps = self.config.burn_in;
        self.total_samples += 1;
        let mut y = minor.items().to_vec();
        y.sort_unstable();
        y
    }

    fn name(&self) -> &'static str {
        "mcmc-updown"
    }
}

/// Greedy MAP seed of exactly `size` items (see
/// [`McmcSampler::seed_items`]).
fn build_seed(kernel: &NdppKernel, size: usize) -> Vec<usize> {
    try_build_seed(kernel, size).unwrap_or_else(|| {
        panic!("no size-{size} subset with positive probability found (kernel rank too low?)")
    })
}

/// Fallible greedy-MAP warm start: `None` when the kernel admits no
/// size-`size` subset with positive determinant (numerically
/// rank-deficient kernels).  Deterministic in the kernel — the
/// coordinator runs this once at registration and hands the result to
/// every [`McmcSampler::with_seed`].
pub fn try_build_seed(kernel: &NdppKernel, size: usize) -> Option<Vec<usize>> {
    let mut items = greedy_map(kernel, size, 0.0).items;
    items.truncate(size);
    if items.len() < size {
        for j in 0..kernel.m() {
            if items.len() == size {
                break;
            }
            if items.contains(&j) {
                continue;
            }
            items.push(j);
            if IncrementalMinor::new(kernel, items.clone()).is_none() {
                items.pop();
            }
        }
    }
    if items.len() == size {
        Some(items)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::probability::{det_l_y, enumerate_probs};
    use crate::util::testing::{
        chi_square_gof, conditioned_on_size, empirical, empirical_from, tv,
    };

    /// Module-level statistical sanity check, deliberately smaller than
    /// the exhaustive cross-sampler suite in `tests/conformance.rs` (which
    /// holds both kernel classes to TV + chi-square at 30k draws) so the
    /// slow restart-mode sampling is not paid twice per CI run.
    #[test]
    fn conformance_smoke_on_ondpp_kernel() {
        let mut rng = Xoshiro::seeded(61);
        let kernel = NdppKernel::random_ondpp(7, 2, &mut rng);
        let size = 3;
        let want = conditioned_on_size(&enumerate_probs(&kernel), size);
        let mut s = McmcSampler::new(&kernel, McmcConfig::for_size(size, 7));
        let n = 8_000;
        let got = empirical(&mut s, 7, n, &mut rng);
        let d = tv(&got, &want);
        assert!(d < 0.06, "tv={d}");
        let cs = chi_square_gof(&got, &want, n);
        assert!(cs.passes(), "chi2 stat={} crit={} df={}", cs.stat, cs.crit_999, cs.df);
        assert!(s.acceptance_rate() > 0.02, "acceptance {}", s.acceptance_rate());
    }

    #[test]
    fn chain_mode_matches_restart_distribution() {
        let mut rng = Xoshiro::seeded(63);
        let kernel = NdppKernel::random_ondpp(6, 2, &mut rng);
        let size = 2;
        let want = conditioned_on_size(&enumerate_probs(&kernel), size);
        let mut s = McmcSampler::new(&kernel, McmcConfig::for_size(size, 6));
        let n = 30_000;
        let mut chain = s.sample_chain(n, &mut rng).into_iter();
        let freq = empirical_from(6, n, &mut rng, |_| chain.next().expect("n chain states"));
        // thinned-chain samples are correlated, so hold only the TV bound
        let d = tv(&freq, &want);
        assert!(d < 0.04, "tv={d}");
    }

    #[test]
    fn samples_are_valid_k_subsets() {
        let mut rng = Xoshiro::seeded(64);
        let kernel = NdppKernel::random_ondpp(40, 4, &mut rng);
        let mut s = McmcSampler::new(&kernel, McmcConfig::for_size(4, 40));
        for _ in 0..10 {
            let y = s.sample(&mut rng);
            assert_eq!(y.len(), 4);
            assert!(y.windows(2).all(|w| w[0] < w[1]), "sorted distinct: {y:?}");
            assert!(y.iter().all(|&i| i < 40));
            assert!(det_l_y(&kernel, &y) > 0.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng_k = Xoshiro::seeded(65);
        let kernel = NdppKernel::random_ondpp(30, 4, &mut rng_k);
        let cfg = McmcConfig::for_size(3, 30);
        let mut s1 = McmcSampler::new(&kernel, cfg);
        let mut s2 = McmcSampler::new(&kernel, cfg);
        let mut r1 = Xoshiro::seeded(9);
        let mut r2 = Xoshiro::seeded(9);
        for _ in 0..5 {
            assert_eq!(s1.sample(&mut r1), s2.sample(&mut r2));
        }
        // restart semantics: a fresh sampler at the same rng point agrees
        let mut s3 = McmcSampler::new(&kernel, cfg);
        let mut r3 = Xoshiro::seeded(9);
        let first = s3.sample(&mut r3);
        let mut s4 = McmcSampler::new(&kernel, cfg);
        let mut r4 = Xoshiro::seeded(9);
        assert_eq!(first, s4.sample(&mut r4));
    }

    #[test]
    fn precomputed_seed_matches_lazy_path() {
        // with_seed (registration-time greedy MAP) and new (lazy greedy
        // MAP) must be byte-identical per rng stream
        let mut rng_k = Xoshiro::seeded(70);
        let kernel = NdppKernel::random_ondpp(30, 4, &mut rng_k);
        let cfg = McmcConfig::for_size(3, 30);
        let seed = try_build_seed(&kernel, 3).expect("healthy kernel has a seed");
        let mut lazy = McmcSampler::new(&kernel, cfg);
        let mut warm = McmcSampler::with_seed(&kernel, cfg, seed);
        let mut r1 = Xoshiro::seeded(5);
        let mut r2 = Xoshiro::seeded(5);
        for _ in 0..3 {
            assert_eq!(lazy.sample(&mut r1), warm.sample(&mut r2));
        }
    }

    #[test]
    fn default_size_tracks_expected_cardinality() {
        let mut rng = Xoshiro::seeded(66);
        let kernel = NdppKernel::random_ondpp(60, 4, &mut rng);
        let cfg = McmcConfig::for_kernel(&kernel);
        let mk = MarginalKernel::build(&kernel);
        let expected: f64 = mk.marginals().iter().sum();
        assert_eq!(cfg.size, (expected.round() as usize).clamp(1, 8));
        assert!(cfg.burn_in >= 200);
        assert!(cfg.thinning >= 1);
    }

    #[test]
    fn survives_kernel_with_diverging_rejection_rate() {
        // the motivating regime: rejection sampling needs thousands of
        // proposals per sample, the chain's per-step cost doesn't care
        let mut rng = Xoshiro::seeded(67);
        let kernel = crate::bench::experiments::nonorthogonal_kernel(64, 24, 1.0, &mut rng);
        let u = crate::ndpp::Proposal::build(&kernel).expected_rejections();
        assert!(u > 100.0, "construction too tame: U={u}");
        let mut s = McmcSampler::new(&kernel, McmcConfig::for_size(10, 64));
        for _ in 0..3 {
            let y = s.sample(&mut rng);
            assert_eq!(y.len(), 10);
            assert!(det_l_y(&kernel, &y) > 0.0);
        }
        assert!(s.acceptance_rate() > 0.0);
    }

    #[test]
    fn size_zero_returns_empty_sets() {
        let mut rng = Xoshiro::seeded(68);
        let kernel = NdppKernel::random_ondpp(12, 2, &mut rng);
        let mut s = McmcSampler::new(
            &kernel,
            McmcConfig { size: 0, burn_in: 10, thinning: 1, refresh_every: 8 },
        );
        assert!(s.sample(&mut rng).is_empty());
        assert_eq!(s.sample_chain(3, &mut rng), vec![Vec::<usize>::new(); 3]);
    }

    #[test]
    #[should_panic(expected = "exceeds kernel rank")]
    fn size_beyond_rank_is_rejected() {
        let mut rng = Xoshiro::seeded(69);
        let kernel = NdppKernel::random_ondpp(12, 2, &mut rng);
        let _ = McmcSampler::new(&kernel, McmcConfig::for_size(5, 12));
    }
}
