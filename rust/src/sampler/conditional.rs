//! Conditional (basket-completion) sampling: all three sampler families
//! driven by a [`ConditionedKernel`], with the serving pipeline's
//! Prepared/Scratch split.
//!
//! Conditioning on an observed basket `J` reduces to swapping the
//! `2K x 2K` inner matrix for the Schur complement `G_J`
//! ([`crate::ndpp::conditional`]); everything `M`-sized is reused from the
//! model's prepared state:
//!
//! * **Conditional Cholesky** — exact, linear time: the conditioned
//!   marginal inner matrix is `W_J = G_J (I + Gram · G_J)^{-1}` with the
//!   *cached* catalog Gram `Z^T Z` (rows/columns of `Z G_J Z^T` vanish on
//!   `J`, so no Gram correction is needed), then the standard sweep
//!   skipping `J`.  With `J = ∅` this is byte-identical to the
//!   unconditional sampler.
//! * **Conditional rejection** — sublinear, and the structural free lunch
//!   of this subsystem: [`SampleTree`] node statistics are sums of
//!   `v_j v_j^T` that do **not** depend on the kernel's inner matrix, so a
//!   conditioned proposal reuses the prepared tree *verbatim*.  Per
//!   request only an `R x R` eigendecomposition is rebuilt: the completion
//!   NDPP `L' = Z G_J Z^T` splits into symmetric + skew parts, the
//!   dominating proposal `L̂' = sym(L') + |skew(L')|` (Theorem 1 applied
//!   to the conditioned kernel) is expressed in the prepared orthonormal
//!   eigenbasis through the cached `basis_map = V_prep^T Z`, and tree
//!   descent runs with a full-rank projector over the prepared node Grams
//!   ([`SampleTree::sample_projected_with`]).  Acceptance is
//!   `det(L'_S) / det(L̂'_S)`, exact by minor domination.
//! * **Conditional fixed-size MCMC** — an [`IncrementalMinor`] seeded from
//!   `J` plus a deterministic greedy completion; the up-down chain swaps
//!   only the non-`J` positions, targeting
//!   `Pr(S) ∝ det(L_{J ∪ S})` at fixed `|S|`.
//!
//! ## The conditioned-state split
//!
//! Everything a basket's requests share — `G_J`, the conditioned marginal
//! `W_J`, the rebuilt proposal eigendecomposition, the MCMC greedy seed —
//! lives in an immutable [`ConditionedState`] behind an `Arc`, so the
//! serving layer can cache it per `(model, J)` and hand it to any shard
//! worker ([`crate::coordinator::ConditioningCache`]).  The
//! [`ConditionalScratch`] keeps only the mutable per-worker hot buffers
//! (Cholesky sweep workspace, descent projector, greedy temporaries) and
//! either builds a state ([`ConditionalScratch::condition`]) or adopts a
//! cached one ([`ConditionalScratch::adopt`]) — adoption performs **zero**
//! eigendecompositions, which [`condition_build_count`] makes observable
//! (the conditional analogue of [`crate::sampler::tree::build_count`]).
//!
//! Per-request conditioning costs `O(|J| K^2 + K^3)` (`+ O(M K^2)` once
//! for the MCMC greedy seed) and allocates only `2K`-sized temporaries;
//! the per-sample hot loops run entirely in the [`ConditionalScratch`]
//! buffers with zero heap allocation beyond the returned subsets, and the
//! prepared tree is never rebuilt (`tests/conditional.rs` pins this via
//! [`crate::sampler::tree::build_count`]).

use std::sync::Arc;

use crate::linalg::backend::{self, Backend as _};
use crate::linalg::{lu, matrix::dot, tridiag::sym_eigen, Matrix};
use crate::ndpp::conditional::{ConditionError, ConditionedKernel};
use crate::ndpp::probability::IncrementalMinor;
use crate::ndpp::{MarginalKernel, NdppKernel};
use crate::rng::Xoshiro;
use crate::sampler::cholesky::{self, CholeskyScratch};
use crate::sampler::elementary::select_elementary_into;
use crate::sampler::mcmc::{
    fill_pos_probs, swap_move, variable_move, BurnInMeter, ItemProposal, McmcConfig, ProposalKind,
};
use crate::sampler::SampleTree;

/// Safety valve for the conditional rejection loop (same contract as the
/// unconditional [`crate::sampler::RejectionSampler`]).
const MAX_PROPOSALS: usize = 5_000_000;

thread_local! {
    /// Count of conditioned-state builds on this thread — every Schur
    /// complement + conditioned-marginal construction, conditioned-proposal
    /// eigendecomposition, and MCMC greedy-seed run increments it.  The
    /// observable half of the hot-basket cache contract: adopting a cached
    /// [`ConditionedState`] leaves the calling thread's counter unchanged
    /// (asserted in `tests/conditional.rs`).  Thread-local so concurrently
    /// running tests cannot race the assertion.
    static CONDITION_BUILDS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of conditioned-state builds performed *by the calling thread*
/// so far (see the thread-local above).
pub fn condition_build_count() -> u64 {
    CONDITION_BUILDS.with(|c| c.get())
}

fn note_condition_build() {
    CONDITION_BUILDS.with(|c| c.set(c.get() + 1));
}

/// Registration-time products shared by every conditional request — the
/// *Prepared* half of the conditional subsystem, frozen on the
/// [`crate::coordinator::ModelEntry`].
#[derive(Debug, Clone)]
pub struct ConditionalPrepared {
    /// `X = diag(I_K, C)`, the model's `2K x 2K` inner matrix.
    pub x: Matrix,
    /// Catalog Gram `Z^T Z` (`2K x 2K`).
    pub gram: Matrix,
    /// `V_prep^T Z` (`R x 2K`): the model factor expressed in the prepared
    /// tree's orthonormal spectral basis — the bridge that lets a
    /// per-request proposal reuse the prepared node statistics.
    pub basis_map: Matrix,
}

impl ConditionalPrepared {
    /// Build from the model's prepared pieces (`O(M K^2 + M R K)` — one
    /// Gram and one skinny GEMM, both through the active backend).
    pub fn build(
        kernel: &NdppKernel,
        marginal: &MarginalKernel,
        tree: &SampleTree,
    ) -> ConditionalPrepared {
        let x = kernel.x_matrix();
        let gram = backend::active().syrk(&marginal.z, 0, marginal.z.rows);
        let basis_map = tree.spectral().vecs.t_matmul(&marginal.z);
        ConditionalPrepared { x, gram, basis_map }
    }

    /// Inner dimension `2K`.
    pub fn k2(&self) -> usize {
        self.x.rows
    }
}

/// The conditioned rejection proposal: the rebuilt `R x R`
/// eigendecomposition of `L̂' = sym(L') + |skew(L')|` in the prepared
/// basis.  Built lazily per basket by
/// [`ConditionalScratch::ensure_rejection`].
#[derive(Debug, Clone)]
struct RejectionState {
    /// conditioned proposal inner matrix `Ĝ` in the prepared basis (R x R)
    ghat: Matrix,
    /// kept eigenvalues of `Ĝ`
    lambda_c: Vec<f64>,
    /// matching eigenvector columns (R x R_kept)
    ucols: Matrix,
    /// `log det(L̂' + I) = Σ log(1 + λ̂_i)`
    logdet_prop_cond: f64,
}

/// The conditional MCMC warm start: chain configuration + deterministic
/// greedy completion seed.  Built lazily per basket by
/// [`ConditionalScratch::ensure_mcmc`].
#[derive(Debug, Clone)]
struct McmcState {
    cfg: McmcConfig,
    /// deterministic greedy completion seed (completion items only)
    seed: Vec<usize>,
    /// conditioned tree-descent weight `basis_map · W_J · basis_map^T`
    /// (`R x R`): item scores under it are the conditioned marginals
    /// `K'_jj`, so tree-driven up-moves propose items proportional to their
    /// completion probability.  Built once per basket from the shared `W_J`
    /// — a [`ConditionedState`] product the cache already distributes —
    /// and cached alongside the seed.
    weight: Matrix,
}

/// Everything one observed basket's requests share, immutable after
/// construction: `G_J` + `log det(L_J)`, the conditioned marginal `W_J`,
/// and (lazily, see the `ensure_*` upgrades) the conditioned rejection
/// proposal and the MCMC warm start.  `Send + Sync`, shared behind an
/// `Arc` — this is the value the serving layer caches per `(model, J)`
/// so hot baskets skip every per-request eigendecomposition.
#[derive(Debug, Clone)]
pub struct ConditionedState {
    /// sorted observed basket
    given: Vec<usize>,
    /// the conditioned kernel (`G_J` + `log det(L_J)`)
    cond: ConditionedKernel,
    /// conditioned marginal inner matrix `W_J = G (I + Gram G)^{-1}`
    w: Matrix,
    /// `log det(L' + I) = log det(I + Gram G)` — the completion normalizer
    logdet_cond: f64,
    rejection: Option<RejectionState>,
    mcmc: Option<McmcState>,
}

impl ConditionedState {
    /// The sorted observed basket this state conditions on.
    pub fn given(&self) -> &[usize] {
        &self.given
    }

    /// Whether the conditioned rejection proposal has been built.
    pub fn has_rejection(&self) -> bool {
        self.rejection.is_some()
    }

    /// Whether the conditional MCMC warm start has been built.
    pub fn has_mcmc(&self) -> bool {
        self.mcmc.is_some()
    }

    /// Heap bytes held by this state (cache byte-budget accounting): the
    /// `2K`/`R`-sized matrices and index vectors, plus a fixed allowance
    /// for the container overheads.
    pub fn memory_bytes(&self) -> usize {
        let f = std::mem::size_of::<f64>();
        let u = std::mem::size_of::<usize>();
        let mut bytes = 256; // struct + Vec/Arc bookkeeping allowance
        bytes += self.given.len() * u * 2; // own copy + the kernel's copy
        bytes += self.cond.g().data.len() * f;
        bytes += self.w.data.len() * f;
        if let Some(r) = &self.rejection {
            bytes += r.ghat.data.len() * f;
            bytes += r.lambda_c.len() * f;
            bytes += r.ucols.data.len() * f;
        }
        if let Some(m) = &self.mcmc {
            bytes += m.seed.len() * u;
        }
        bytes
    }

    /// Union of two states for the same basket: start from `new` and take
    /// any lazily built part only `old` has.  The cache's merge-on-insert
    /// uses this so an MCMC upgrade published later never discards a
    /// rejection upgrade published earlier (and vice versa) — without it,
    /// mixed-algorithm hot baskets would thrash between part rebuilds.
    pub fn merged(
        new: &Arc<ConditionedState>,
        old: &Arc<ConditionedState>,
    ) -> Arc<ConditionedState> {
        let need_rejection = new.rejection.is_none() && old.rejection.is_some();
        let need_mcmc = new.mcmc.is_none() && old.mcmc.is_some();
        if !need_rejection && !need_mcmc {
            return Arc::clone(new);
        }
        let mut merged = (**new).clone();
        if need_rejection {
            merged.rejection = old.rejection.clone();
        }
        if need_mcmc {
            merged.mcmc = old.mcmc.clone();
        }
        Arc::new(merged)
    }
}

/// Merge the (sorted) completion with the (sorted) observed basket into
/// the full sampled set.
fn merge_sorted(given: &[usize], s: Vec<usize>) -> Vec<usize> {
    if given.is_empty() {
        return s;
    }
    let mut out = Vec::with_capacity(given.len() + s.len());
    let (mut a, mut b) = (0usize, 0usize);
    while a < given.len() || b < s.len() {
        let take_given = b >= s.len() || (a < given.len() && given[a] < s[b]);
        if take_given {
            out.push(given[a]);
            a += 1;
        } else {
            out.push(s[b]);
            b += 1;
        }
    }
    out
}

/// Per-worker conditional workspace: the current request's (possibly
/// cache-adopted) [`ConditionedState`] plus every mutable hot buffer the
/// sample loops touch.  One scratch per (worker, model); a new request
/// re-conditions (or adopts) in place, samples within a request reuse
/// everything.
pub struct ConditionalScratch {
    /// shared conditioned products of the current request's basket
    state: Option<Arc<ConditionedState>>,
    /// Cholesky sweep workspace
    chol: CholeskyScratch,
    /// descent projector `Q̃` (R x R) + downdate / score buffers
    qt: Matrix,
    qa: Vec<f64>,
    scores: Vec<f64>,
    /// selected elementary component indices
    e: Vec<usize>,
    /// proposals drawn for the most recent rejection sample
    pub last_proposals: usize,
    /// greedy workspace: running `G_T`, per-item scores, two matvecs
    gt: Matrix,
    item_scores: Vec<f64>,
    gu: Vec<f64>,
    gv: Vec<f64>,
    /// proposal kind the next `ensure_mcmc` bakes into the chain config
    /// (the service sets this from its registration-time default)
    mcmc_proposal: ProposalKind,
    /// chain proposal of the current basket (weight + leaf scratch),
    /// rebuilt lazily when the state changes
    chain_prop: Option<ItemProposal>,
    /// per-position proposal probabilities of the running chain
    pos_prob: Vec<f64>,
    /// chain move counters since the last [`ConditionalScratch::
    /// take_mcmc_stats`] — proposed, accepted, and the Rao-Blackwellized
    /// sum of closed-form acceptance probabilities over proposed moves
    /// (expected-acceptance telemetry; self-loops contribute 0)
    mcmc_steps: u64,
    mcmc_accepts: u64,
    mcmc_expected: f64,
}

impl Default for ConditionalScratch {
    fn default() -> ConditionalScratch {
        ConditionalScratch {
            state: None,
            chol: CholeskyScratch::new(),
            qt: Matrix::default(),
            qa: Vec::new(),
            scores: Vec::new(),
            e: Vec::new(),
            last_proposals: 0,
            gt: Matrix::default(),
            item_scores: Vec::new(),
            gu: Vec::new(),
            gv: Vec::new(),
            mcmc_proposal: ProposalKind::default(),
            chain_prop: None,
            pos_prob: Vec::new(),
            mcmc_steps: 0,
            mcmc_accepts: 0,
            mcmc_expected: 0.0,
        }
    }
}

impl ConditionalScratch {
    pub fn new() -> ConditionalScratch {
        ConditionalScratch::default()
    }

    /// Condition on a new observed basket: validates `given`, computes
    /// `G_J` and the conditioned marginal, and replaces any previously
    /// held state.  `z` is the model's `M x 2K` factor (shared, e.g.
    /// [`MarginalKernel::z`]).
    pub fn condition(
        &mut self,
        prep: &ConditionalPrepared,
        z: &Matrix,
        given: &[usize],
    ) -> Result<(), ConditionError> {
        let cond = ConditionedKernel::from_zx(z, &prep.x, given)?;
        // conditioned marginal: rows of Z G_J Z^T vanish exactly on J, so
        // the FULL catalog Gram is correct with no per-request correction
        let mut a = prep.gram.matmul(cond.g());
        a.add_diag(1.0);
        let lu = lu::Lu::factor(&a);
        let (sign, logdet) = lu.slogdet();
        if lu.singular || sign <= 0.0 || !logdet.is_finite() {
            return Err(ConditionError::SingularMinor);
        }
        let w = cond.g().matmul(&lu.inverse());
        self.state = Some(Arc::new(ConditionedState {
            given: cond.given().to_vec(),
            cond,
            w,
            logdet_cond: logdet,
            rejection: None,
            mcmc: None,
        }));
        self.last_proposals = 0;
        self.chain_prop = None;
        note_condition_build();
        Ok(())
    }

    /// Adopt a previously built (cached) state for the current request —
    /// the cache-hit path.  Performs no linear algebra at all: the state
    /// already holds `G_J`, `W_J`, and whatever `ensure_*` upgrades its
    /// builder ran, so [`condition_build_count`] stays unchanged.
    pub fn adopt(&mut self, state: Arc<ConditionedState>) {
        self.state = Some(state);
        self.last_proposals = 0;
        self.chain_prop = None;
    }

    /// The shareable conditioned state of the current request (`None`
    /// before the first successful [`ConditionalScratch::condition`] /
    /// [`ConditionalScratch::adopt`]).  Cheap `Arc` clone — this is what
    /// the serving layer publishes to the conditioning cache.
    pub fn shared_state(&self) -> Option<Arc<ConditionedState>> {
        self.state.clone()
    }

    fn state(&self) -> &ConditionedState {
        self.state.as_deref().expect("condition() before sampling")
    }

    /// The sorted observed basket of the current request (empty before
    /// conditioning).
    pub fn given(&self) -> &[usize] {
        self.state.as_deref().map(|s| s.given.as_slice()).unwrap_or(&[])
    }

    /// The conditioned kernel of the current request.
    ///
    /// # Panics
    /// When no [`ConditionalScratch::condition`] call has succeeded yet.
    pub fn conditioned(&self) -> &ConditionedKernel {
        &self.state().cond
    }

    /// `log det(L' + I)` — the completion NDPP's normalizer.
    pub fn logdet_cond(&self) -> f64 {
        self.state().logdet_cond
    }

    /// Whether the conditioned rejection proposal is built for the current
    /// basket (either by [`ConditionalScratch::ensure_rejection`] or by
    /// the builder of an adopted cached state).
    pub fn rejection_ready(&self) -> bool {
        self.state.as_deref().is_some_and(|s| s.rejection.is_some())
    }

    /// Whether the conditional MCMC warm start is built for the current
    /// basket.
    pub fn mcmc_ready(&self) -> bool {
        self.state.as_deref().is_some_and(|s| s.mcmc.is_some())
    }

    /// Expected completion size `E|S| = tr(K') = tr(W_J · Gram)`.
    pub fn expected_completion_size(&self, prep: &ConditionalPrepared) -> f64 {
        let k2 = prep.k2();
        let w = &self.state().w;
        let mut tr = 0.0;
        for i in 0..k2 {
            // Gram is symmetric, so its i-th column is its i-th row
            tr += dot(w.row(i), prep.gram.row(i));
        }
        tr
    }

    // ---- conditional Cholesky -------------------------------------------

    /// Exact linear-time conditional sample: the standard `O(M K^2)` sweep
    /// over the conditioned marginal, skipping `J`.  Returns the **full**
    /// basket (`J ∪ S`, sorted) and the completion's log-probability
    /// `log Pr(S | J ⊆ Y)`.
    pub fn sample_cholesky(&mut self, z: &Matrix, rng: &mut Xoshiro) -> (Vec<usize>, f64) {
        let st = self.state.clone().expect("condition() before sampling");
        let (s, logp) = cholesky::sweep_skipping(z, &st.w, &mut self.chol, &st.given, rng);
        (merge_sorted(&st.given, s), logp)
    }

    // ---- conditional rejection (tree reuse) -----------------------------

    /// Build the conditioned proposal: split `G_J` into symmetric + skew
    /// parts, push both through the cached `basis_map`, replace the skew
    /// part by its polar factor (`|A| = (A^T A)^{1/2}` — Theorem 1's
    /// dominating construction applied to the conditioned kernel), and
    /// eigendecompose the resulting `R x R` inner matrix.  This is the
    /// *only* per-request preprocessing of the rejection path — the
    /// prepared [`SampleTree`] is reused untouched.
    ///
    /// Returns `true` when the proposal was built here (the state gained a
    /// part, so a caching layer should re-publish it) and `false` when the
    /// current state already carried it (cache hit: zero work).
    pub fn ensure_rejection(&mut self, prep: &ConditionalPrepared, tree: &SampleTree) -> bool {
        if self.rejection_ready() {
            return false;
        }
        let part = {
            let st = self.state();
            let g = st.cond.g();
            let k2 = g.rows;
            let r = tree.spectral().rank();
            let gs = Matrix::from_fn(k2, k2, |i, j| 0.5 * (g[(i, j)] + g[(j, i)]));
            let ga = Matrix::from_fn(k2, k2, |i, j| 0.5 * (g[(i, j)] - g[(j, i)]));
            // sym and skew inner matrices in the prepared orthonormal basis
            let bsym = prep.basis_map.matmul(&gs).matmul_t(&prep.basis_map);
            let bskew = prep.basis_map.matmul(&ga).matmul_t(&prep.basis_map);
            // |skew| via its polar factor (A^T A = -A^2 is symmetric PSD)
            let polar = sym_eigen(&bskew.t_matmul(&bskew)).sqrt();
            let ghat = bsym.add(&polar);
            let eig = sym_eigen(&ghat);
            let logdet_prop_cond =
                eig.values.iter().map(|&l| (1.0 + l.max(0.0)).ln()).sum();
            let max_l = eig.values.first().copied().unwrap_or(0.0).max(0.0);
            let cutoff = 1e-12 * max_l.max(1e-300);
            let kept: Vec<usize> =
                (0..eig.values.len()).filter(|&i| eig.values[i] > cutoff).collect();
            let lambda_c: Vec<f64> = kept.iter().map(|&i| eig.values[i]).collect();
            let mut ucols = Matrix::zeros(r, kept.len());
            for (out_i, &i) in kept.iter().enumerate() {
                for a in 0..r {
                    ucols[(a, out_i)] = eig.vectors[(a, i)];
                }
            }
            RejectionState { ghat, lambda_c, ucols, logdet_prop_cond }
        };
        Arc::make_mut(self.state.as_mut().expect("state checked above")).rejection = Some(part);
        note_condition_build();
        true
    }

    /// Expected proposals per accepted conditional sample:
    /// `U_J = det(L̂' + I) / det(L' + I)`.
    ///
    /// # Panics
    /// When [`ConditionalScratch::ensure_rejection`] has not run for the
    /// current basket (and the adopted state does not carry the proposal).
    pub fn expected_rejections(&self) -> f64 {
        let st = self.state();
        let rej = st.rejection.as_ref().expect("ensure_rejection() first");
        (rej.logdet_prop_cond - st.logdet_cond).exp()
    }

    /// Draw one conditional sample by rejection: propose from the
    /// conditioned symmetric DPP via projected tree descent, accept with
    /// `det(L'_S) / det(L̂'_S)`.  Returns the full basket (`J ∪ S`).
    pub fn sample_rejection(
        &mut self,
        z: &Matrix,
        tree: &SampleTree,
        rng: &mut Xoshiro,
    ) -> Vec<usize> {
        let st = self.state.clone().expect("condition() before sampling");
        let rej = st.rejection.as_ref().expect("ensure_rejection() first");
        let r = tree.spectral().rank();
        for attempt in 1..=MAX_PROPOSALS {
            select_elementary_into(&rej.lambda_c, &mut self.e, rng);
            let s = if self.e.is_empty() {
                Vec::new()
            } else {
                // Q̃ = U_E U_E^T — the selected subspace in the prepared
                // basis
                self.qt.reset_zeros(r, r);
                for &ei in self.e.iter() {
                    for a in 0..r {
                        let ua = rej.ucols[(a, ei)];
                        if ua == 0.0 {
                            continue;
                        }
                        let qrow = self.qt.row_mut(a);
                        for (b, qv) in qrow.iter_mut().enumerate() {
                            *qv += ua * rej.ucols[(b, ei)];
                        }
                    }
                }
                tree.sample_projected_with(
                    &mut self.qt,
                    self.e.len(),
                    &st.given,
                    &mut self.qa,
                    &mut self.scores,
                    rng,
                )
            };
            // acceptance: det(L'_S) / det(L̂'_S)
            let accept = if s.is_empty() {
                1.0
            } else {
                let num = st.cond.completion_det(z, &s);
                let v_s = tree.spectral().vecs.gather_rows(&s);
                let den = lu::det(&v_s.matmul(&rej.ghat).matmul_t(&v_s));
                if den <= 0.0 {
                    0.0
                } else {
                    (num / den).clamp(0.0, 1.0)
                }
            };
            if rng.uniform() <= accept {
                self.last_proposals = attempt;
                return merge_sorted(&st.given, s);
            }
        }
        panic!(
            "conditional rejection sampler exceeded {MAX_PROPOSALS} proposals — \
             expected rate {:.3e}; use conditional MCMC for this kernel/basket",
            self.expected_rejections()
        );
    }

    // ---- conditional fixed-size MCMC ------------------------------------

    /// Build the conditional MCMC configuration: completion size from the
    /// conditioned marginal trace (clamped by the remaining rank
    /// `2K − |J|`), plus a deterministic greedy completion seed grown by
    /// rank-1 Schur updates of `G_T` (`O(M K^2)` once, then `O(M K)` per
    /// pick) and validated against the exact `IncrementalMinor`
    /// factorization the chain uses — a numerically borderline basket
    /// shrinks the seed instead of panicking later in a served request.
    ///
    /// Returns `true` when the warm start was built here (re-publish to
    /// the cache) and `false` when the state already carried it.
    pub fn ensure_mcmc(
        &mut self,
        prep: &ConditionalPrepared,
        z: &Matrix,
        kernel: &NdppKernel,
    ) -> bool {
        if self.mcmc_ready() {
            return false;
        }
        let st = self.state.clone().expect("condition() before sampling");
        let m = z.rows;
        let k2 = prep.k2();
        let cap = (k2.saturating_sub(st.given.len())).min(m - st.given.len());
        let size = if cap == 0 {
            0
        } else {
            (self.expected_completion_size(prep).round() as usize).clamp(1, cap)
        };
        // greedy seed: repeatedly take the highest conditional score,
        // updating G_T by the Schur rank-1 downdate after each pick
        let mut seed: Vec<usize> = Vec::with_capacity(size);
        {
            let g = st.cond.g();
            let ConditionalScratch { gt, item_scores, gu, gv, .. } = &mut *self;
            gt.reset_zeros(k2, k2);
            gt.data.copy_from_slice(&g.data);
            item_scores.clear();
            item_scores.extend((0..m).map(|i| gt.bilinear(z.row(i), z.row(i))));
            for &a in st.given.iter() {
                item_scores[a] = 0.0;
            }
            let scale = item_scores.iter().cloned().fold(0.0f64, f64::max).max(1e-300);
            for _ in 0..size {
                let mut best = usize::MAX;
                let mut best_p = 1e-12 * scale;
                for (i, &p) in item_scores.iter().enumerate() {
                    if p > best_p && !seed.contains(&i) {
                        best = i;
                        best_p = p;
                    }
                }
                if best == usize::MAX {
                    break; // remaining rank exhausted: shorter completion
                }
                let zi = z.row(best);
                gu.clear();
                gv.clear();
                for a in 0..k2 {
                    gu.push(dot(gt.row(a), zi));
                }
                for b in 0..k2 {
                    let mut acc = 0.0;
                    for a in 0..k2 {
                        acc += zi[a] * gt[(a, b)];
                    }
                    gv.push(acc);
                }
                let p = dot(zi, gu);
                // score_j <- score_j − (z_j·gu)(gv·z_j)/p, zeroing the pick
                let inv = 1.0 / p;
                for (j, sc) in item_scores.iter_mut().enumerate() {
                    if *sc == 0.0 {
                        continue;
                    }
                    let zj = z.row(j);
                    *sc -= dot(zj, gu) * dot(gv, zj) * inv;
                }
                gt.rank1_sub(gu, gv, inv);
                item_scores[best] = 0.0;
                seed.push(best);
            }
        }
        // The greedy Schur chain and a fresh LU can disagree on
        // numerically borderline baskets (det(L_J) near the admission
        // floor, picks near the score threshold).  Validate the seed
        // against the same factorization `sample_mcmc` constructs and
        // shrink until the minor admits it, so serving never panics on
        // request content; the chain then runs at the admitted size
        // (possibly 0 = observed basket only).
        while !seed.is_empty() {
            let start: Vec<usize> = st.given.iter().chain(seed.iter()).copied().collect();
            if IncrementalMinor::new(kernel, start).is_some() {
                break;
            }
            seed.pop();
        }
        let actual = seed.len();
        let mut cfg = McmcConfig::for_size(actual, m);
        cfg.size = actual;
        cfg.proposal = self.mcmc_proposal;
        // conditioned descent weight in the prepared basis: item scores
        // under `basis_map W_J basis_map^T` are the conditioned marginals
        // `K'_jj = z_j^T W_J z_j`, so up-moves propose proportional to
        // completion probability.  Deterministic in `(kernel, J)` alone —
        // never in which other lazy parts the cached state happens to
        // carry — so replay across shard counts is unaffected.
        let weight = prep.basis_map.matmul(&st.w).matmul_t(&prep.basis_map);
        Arc::make_mut(self.state.as_mut().expect("state checked above")).mcmc =
            Some(McmcState { cfg, seed, weight });
        self.chain_prop = None;
        note_condition_build();
        true
    }

    /// The chain configuration chosen by [`ConditionalScratch::ensure_mcmc`].
    pub fn mcmc_config(&self) -> McmcConfig {
        self.state().mcmc.as_ref().expect("ensure_mcmc() first").cfg
    }

    /// Set the proposal kind the next [`ConditionalScratch::ensure_mcmc`]
    /// bakes into the chain config (the service's registration-time
    /// default; [`ProposalKind::Tree`] unless pinned).
    pub fn set_mcmc_proposal(&mut self, kind: ProposalKind) {
        self.mcmc_proposal = kind;
    }

    /// The proposal kind conditional chains run with: from the built warm
    /// start when ready, otherwise the configured default.
    pub fn mcmc_proposal_kind(&self) -> ProposalKind {
        self.state
            .as_deref()
            .and_then(|s| s.mcmc.as_ref())
            .map(|mc| mc.cfg.proposal)
            .unwrap_or(self.mcmc_proposal)
    }

    /// `(proposed, accepted, expected_accept_mass)` chain moves since the
    /// last call, for per-request acceptance-rate reporting — the third
    /// element is the Rao-Blackwellized sum of closed-form acceptance
    /// probabilities, so `expected / proposed` estimates the same rate
    /// `accepted / proposed` does, at lower variance.  Resets the
    /// counters.
    pub fn take_mcmc_stats(&mut self) -> (u64, u64, f64) {
        let out = (self.mcmc_steps, self.mcmc_accepts, self.mcmc_expected);
        self.mcmc_steps = 0;
        self.mcmc_accepts = 0;
        self.mcmc_expected = 0.0;
        out
    }

    /// Build (or reuse) the chain's candidate-item proposal for the
    /// current basket: the conditioned descent weight cached on the warm
    /// start, with `J` statically excluded.
    fn ensure_chain_prop(&mut self, st: &ConditionedState, m: usize) {
        if self.chain_prop.is_some() {
            return;
        }
        let mc = st.mcmc.as_ref().expect("ensure_mcmc() first");
        self.chain_prop = Some(match mc.cfg.proposal {
            ProposalKind::Uniform => ItemProposal::uniform(m),
            ProposalKind::Tree => ItemProposal::tree(mc.weight.clone(), st.given.clone(), m),
        });
    }

    /// Draw one conditional fixed-size sample: restart the up-down chain
    /// from `J ∪ seed`, swap only non-`J` positions (target
    /// `Pr(S) ∝ det(L_{J ∪ S})`, `|S|` fixed), with candidates drawn
    /// through the prepared tree under the conditioned weight (uniform
    /// when pinned) and adaptive burn-in bounded by the config knobs.
    /// Returns the full basket together with the chain steps spent.
    pub fn sample_mcmc(
        &mut self,
        kernel: &NdppKernel,
        tree: &SampleTree,
        rng: &mut Xoshiro,
    ) -> (Vec<usize>, u64) {
        let (mut sets, steps) = self.run_mcmc_chain(kernel, tree, 1, false, rng);
        (sets.pop().expect("one chain state"), steps)
    }

    /// Satellite of the tree-proposal chain: draw `n` conditional samples
    /// from **one** thinned chain instead of `n` burn-in restarts —
    /// amortized burn-in for `n > 1` requests that opt into chain mode on
    /// the wire.  Successive states are correlated at lags shorter than
    /// the chain's mixing time; restart mode stays the replay default.
    pub fn sample_mcmc_chain(
        &mut self,
        kernel: &NdppKernel,
        tree: &SampleTree,
        n: usize,
        rng: &mut Xoshiro,
    ) -> (Vec<Vec<usize>>, u64) {
        self.run_mcmc_chain(kernel, tree, n, false, rng)
    }

    /// Variable-size conditional chain: target the **full** conditional
    /// law `Pr(Y | J ⊆ Y) ∝ det(L_Y)` over completions of any size (the
    /// same law the rejection path samples), via up/down/swap moves over
    /// the non-`J` positions.  This is what the steering router's
    /// `auto` → MCMC fallthrough runs, so steered answers match the
    /// distribution the feasible path would have produced.
    pub fn sample_mcmc_variable(
        &mut self,
        kernel: &NdppKernel,
        tree: &SampleTree,
        rng: &mut Xoshiro,
    ) -> (Vec<usize>, u64) {
        let (mut sets, steps) = self.run_mcmc_chain(kernel, tree, 1, true, rng);
        (sets.pop().expect("one chain state"), steps)
    }

    /// Variable-size chain-mode batch (see
    /// [`ConditionalScratch::sample_mcmc_chain`]).
    pub fn sample_mcmc_variable_chain(
        &mut self,
        kernel: &NdppKernel,
        tree: &SampleTree,
        n: usize,
        rng: &mut Xoshiro,
    ) -> (Vec<Vec<usize>>, u64) {
        self.run_mcmc_chain(kernel, tree, n, true, rng)
    }

    /// Shared chain driver behind the four `sample_mcmc*` entry points:
    /// adaptive burn-in from the validated `J ∪ seed` start, then `n - 1`
    /// thinned records.  `variable` selects up/down/swap moves over the
    /// completion positions (cardinality-free target) versus swap-only
    /// (fixed completion size).
    fn run_mcmc_chain(
        &mut self,
        kernel: &NdppKernel,
        tree: &SampleTree,
        n: usize,
        variable: bool,
        rng: &mut Xoshiro,
    ) -> (Vec<Vec<usize>>, u64) {
        let st = self.state.clone().expect("condition() before sampling");
        let mc = st.mcmc.as_ref().expect("ensure_mcmc() first");
        let cfg = mc.cfg;
        if n == 0 {
            return (Vec::new(), 0);
        }
        let jlen = st.given.len();
        if cfg.size == 0 && !variable {
            return (vec![st.given.clone(); n], 0);
        }
        let m = kernel.m();
        let cap = m.min(2 * kernel.k());
        let start: Vec<usize> = st.given.iter().chain(mc.seed.iter()).copied().collect();
        // ensure_mcmc validated this exact (deterministic) factorization;
        // degrade to the observed basket rather than panicking a served
        // request if a caller mixed up kernels across models
        let Some(mut minor) = IncrementalMinor::new(kernel, start.clone()) else {
            debug_assert!(false, "seed validated by ensure_mcmc but minor refused it");
            return (vec![st.given.clone(); n], 0);
        };
        minor.refresh_every = cfg.refresh_every.max(1);
        self.ensure_chain_prop(&st, m);
        let ConditionalScratch {
            chain_prop, pos_prob, mcmc_steps, mcmc_accepts, mcmc_expected, ..
        } = &mut *self;
        let prop = chain_prop.as_mut().expect("just built");
        fill_pos_probs(prop, Some(tree), minor.items(), pos_prob);
        let burn_cap = cfg.burn_in;
        let floor = (burn_cap / 4).max(crate::sampler::mcmc::BURN_WINDOW).min(burn_cap);
        let mut meter = BurnInMeter::new();
        let mut steps: u64 = 0;
        let mut one_move = |minor: &mut IncrementalMinor<'_>,
                            pos_prob: &mut Vec<f64>,
                            prop: &mut ItemProposal,
                            rng: &mut Xoshiro| {
            *mcmc_steps += 1;
            let (accepted, p_accept) = if variable {
                variable_move(minor, jlen, cap, prop, Some(tree), pos_prob, rng)
            } else {
                swap_move(minor, jlen, prop, Some(tree), pos_prob, rng)
            };
            *mcmc_expected += p_accept;
            if accepted {
                *mcmc_accepts += 1;
            }
            if !minor.is_healthy() {
                // drift recovery: restart from the validated seed (same
                // deterministic construction as above, so it succeeds)
                match IncrementalMinor::new(kernel, start.clone()) {
                    Some(mut fresh) => {
                        fresh.refresh_every = cfg.refresh_every.max(1);
                        fill_pos_probs(prop, Some(tree), fresh.items(), pos_prob);
                        *minor = fresh;
                    }
                    None => return false,
                }
            }
            true
        };
        let mut burn = 0usize;
        while burn < burn_cap {
            if !one_move(&mut minor, pos_prob, prop, rng) {
                break;
            }
            burn += 1;
            if cfg.adaptive_burn_in && meter.record(minor.log_det()) && burn >= floor {
                break;
            }
        }
        steps += burn as u64;
        let mut out = Vec::with_capacity(n);
        for idx in 0..n {
            if idx > 0 {
                for _ in 0..cfg.thinning {
                    if !one_move(&mut minor, pos_prob, prop, rng) {
                        break;
                    }
                    steps += 1;
                }
            }
            let mut y = minor.items().to_vec();
            y.sort_unstable();
            out.push(y);
        }
        (out, steps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ndpp::Proposal;
    use crate::sampler::TreeConfig;

    fn prepared(kernel: &NdppKernel) -> (MarginalKernel, SampleTree, ConditionalPrepared) {
        let marginal = MarginalKernel::build(kernel);
        let proposal = Proposal::build(kernel);
        let tree = SampleTree::build(&proposal.spectral(), TreeConfig { leaf_size: 2 });
        let prep = ConditionalPrepared::build(kernel, &marginal, &tree);
        (marginal, tree, prep)
    }

    #[test]
    fn empty_given_cholesky_is_byte_identical_to_unconditional() {
        let mut rng = Xoshiro::seeded(21);
        let kernel = NdppKernel::random_ondpp(24, 4, &mut rng);
        let (marginal, _tree, prep) = prepared(&kernel);
        let mut scratch = ConditionalScratch::new();
        scratch.condition(&prep, &marginal.z, &[]).unwrap();
        assert_eq!(
            scratch.state().w.data,
            marginal.w.data,
            "conditioned W_∅ must equal W"
        );
        let mut chol = CholeskyScratch::for_marginal(&marginal);
        let mut r1 = Xoshiro::seeded(77);
        let mut r2 = Xoshiro::seeded(77);
        for _ in 0..10 {
            let (y1, lp1) = scratch.sample_cholesky(&marginal.z, &mut r1);
            let (y2, lp2) = cholesky::sample_with_logprob_into(&marginal, &mut chol, &mut r2);
            assert_eq!(y1, y2);
            assert_eq!(lp1.to_bits(), lp2.to_bits());
        }
    }

    #[test]
    fn conditional_samples_always_contain_given() {
        let mut rng = Xoshiro::seeded(22);
        let kernel = NdppKernel::random_ondpp(20, 4, &mut rng);
        let (marginal, tree, prep) = prepared(&kernel);
        let mut scratch = ConditionalScratch::new();
        let given = vec![3usize, 11];
        scratch.condition(&prep, &marginal.z, &given).unwrap();
        assert!(scratch.ensure_rejection(&prep, &tree), "first build reports an upgrade");
        assert!(scratch.ensure_mcmc(&prep, &marginal.z, &kernel));
        for _ in 0..20 {
            let (y, _) = scratch.sample_cholesky(&marginal.z, &mut rng);
            assert!(given.iter().all(|g| y.contains(g)), "cholesky lost given: {y:?}");
            assert!(y.windows(2).all(|w| w[0] < w[1]), "not sorted-distinct: {y:?}");
            let y = scratch.sample_rejection(&marginal.z, &tree, &mut rng);
            assert!(given.iter().all(|g| y.contains(g)), "rejection lost given: {y:?}");
            assert!(y.windows(2).all(|w| w[0] < w[1]));
            let (y, _) = scratch.sample_mcmc(&kernel, &tree, &mut rng);
            assert!(given.iter().all(|g| y.contains(g)), "mcmc lost given: {y:?}");
            assert!(y.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn expected_rejections_are_finite_and_at_least_one() {
        let mut rng = Xoshiro::seeded(23);
        let kernel = NdppKernel::random_ondpp(18, 4, &mut rng);
        let (marginal, tree, prep) = prepared(&kernel);
        let mut scratch = ConditionalScratch::new();
        scratch.condition(&prep, &marginal.z, &[2, 9]).unwrap();
        scratch.ensure_rejection(&prep, &tree);
        let u = scratch.expected_rejections();
        assert!(u.is_finite() && u >= 1.0 - 1e-9, "U={u}");
    }

    #[test]
    fn reconditioning_resets_request_state() {
        let mut rng = Xoshiro::seeded(24);
        let kernel = NdppKernel::random_ondpp(16, 4, &mut rng);
        let (marginal, tree, prep) = prepared(&kernel);
        let mut scratch = ConditionalScratch::new();
        scratch.condition(&prep, &marginal.z, &[1]).unwrap();
        scratch.ensure_rejection(&prep, &tree);
        scratch.ensure_mcmc(&prep, &marginal.z, &kernel);
        let u1 = scratch.expected_rejections();
        // new basket invalidates the conditioned proposal + seed
        scratch.condition(&prep, &marginal.z, &[1, 6]).unwrap();
        assert!(!scratch.rejection_ready() && !scratch.mcmc_ready());
        scratch.ensure_rejection(&prep, &tree);
        scratch.ensure_mcmc(&prep, &marginal.z, &kernel);
        let u2 = scratch.expected_rejections();
        assert!(u1.is_finite() && u2.is_finite());
        // samples from the new basket contain the new item
        let y = scratch.sample_rejection(&marginal.z, &tree, &mut rng);
        assert!(y.contains(&6));
    }

    #[test]
    fn adopted_state_samples_identically_with_zero_builds() {
        // build once, adopt into a fresh scratch: same request stream is
        // byte-identical and the adopting thread performs zero builds
        let mut krng = Xoshiro::seeded(26);
        let kernel = NdppKernel::random_ondpp(24, 4, &mut krng);
        let (marginal, tree, prep) = prepared(&kernel);
        let mut builder = ConditionalScratch::new();
        builder.condition(&prep, &marginal.z, &[2, 9]).unwrap();
        builder.ensure_rejection(&prep, &tree);
        builder.ensure_mcmc(&prep, &marginal.z, &kernel);
        let state = builder.shared_state().expect("state exists after condition");
        assert!(state.has_rejection() && state.has_mcmc());
        assert!(state.memory_bytes() > 0);

        let mut adopter = ConditionalScratch::new();
        let before = condition_build_count();
        adopter.adopt(Arc::clone(&state));
        assert!(!adopter.ensure_rejection(&prep, &tree), "adopted proposal rebuilt");
        assert!(!adopter.ensure_mcmc(&prep, &marginal.z, &kernel), "adopted seed rebuilt");
        assert_eq!(condition_build_count(), before, "adoption must be build-free");
        assert_eq!(
            adopter.expected_rejections().to_bits(),
            builder.expected_rejections().to_bits()
        );
        let mut r1 = Xoshiro::seeded(5);
        let mut r2 = Xoshiro::seeded(5);
        for _ in 0..10 {
            assert_eq!(
                builder.sample_rejection(&marginal.z, &tree, &mut r1),
                adopter.sample_rejection(&marginal.z, &tree, &mut r2)
            );
        }
        let mut r1 = Xoshiro::seeded(6);
        let mut r2 = Xoshiro::seeded(6);
        for _ in 0..5 {
            assert_eq!(
                builder.sample_mcmc(&kernel, &tree, &mut r1),
                adopter.sample_mcmc(&kernel, &tree, &mut r2)
            );
            assert_eq!(
                builder.sample_cholesky(&marginal.z, &mut r1),
                adopter.sample_cholesky(&marginal.z, &mut r2)
            );
        }
    }

    #[test]
    fn merged_states_keep_the_union_of_parts() {
        let mut krng = Xoshiro::seeded(27);
        let kernel = NdppKernel::random_ondpp(20, 4, &mut krng);
        let (marginal, tree, prep) = prepared(&kernel);
        let mut a = ConditionalScratch::new();
        a.condition(&prep, &marginal.z, &[3]).unwrap();
        a.ensure_rejection(&prep, &tree);
        let rej_only = a.shared_state().unwrap();
        let mut b = ConditionalScratch::new();
        b.condition(&prep, &marginal.z, &[3]).unwrap();
        b.ensure_mcmc(&prep, &marginal.z, &kernel);
        let mcmc_only = b.shared_state().unwrap();

        let merged = ConditionedState::merged(&mcmc_only, &rej_only);
        assert!(merged.has_rejection() && merged.has_mcmc());
        assert!(merged.memory_bytes() >= rej_only.memory_bytes());
        // no parts to graft: merged() returns the new state unchanged
        let same = ConditionedState::merged(&merged, &rej_only);
        assert!(Arc::ptr_eq(&same, &merged));
    }

    #[test]
    fn full_basket_conditioning_returns_given_only() {
        // |J| = 2K: the completion is a.s. empty for every sampler
        let mut rng = Xoshiro::seeded(25);
        let kernel = NdppKernel::random_ondpp(12, 2, &mut rng);
        let (marginal, tree, prep) = prepared(&kernel);
        let mut scratch = ConditionalScratch::new();
        let given = vec![0usize, 3, 7, 10];
        scratch.condition(&prep, &marginal.z, &given).unwrap();
        scratch.ensure_rejection(&prep, &tree);
        scratch.ensure_mcmc(&prep, &marginal.z, &kernel);
        assert_eq!(scratch.mcmc_config().size, 0);
        for _ in 0..10 {
            assert_eq!(scratch.sample_cholesky(&marginal.z, &mut rng).0, given);
            assert_eq!(scratch.sample_rejection(&marginal.z, &tree, &mut rng), given);
            assert_eq!(scratch.sample_mcmc(&kernel, &tree, &mut rng).0, given);
            assert_eq!(scratch.sample_mcmc_variable(&kernel, &tree, &mut rng).0, given);
        }
    }
}
