//! `cargo bench --bench linalg_backends` — the compute-backend sweep
//! (three-way: naive / blocked / simd, with the detected SIMD ISA and
//! the resolved thread budget recorded in the JSON).
//!
//! Four measurement families:
//!
//! 1. **GEMM shapes** — square products at 128/256/512 (plus 1024 in full
//!    mode) and the skinny `M x 2K` panel shapes the samplers actually
//!    produce, under every [`BackendKind`] plus the simd backend's
//!    unpacked reference walk, so the packed-panel win lands in the
//!    record as `packed_vs_unpacked`.  Backends are invoked directly (no
//!    global flipping), so the comparison is apples-to-apples on
//!    identical inputs.
//! 2. **Pool vs spawn** — the skinny `M x 2K` panel sweep run through the
//!    persistent compute pool ([`backend::fan_out_rows`]) and through
//!    the legacy spawn-per-call fan-out
//!    ([`crate::linalg::backend::SimdBackend::gemm_spawn_fanout`]); small
//!    panels are exactly where `std::thread::scope` spawn cost used to
//!    dominate.
//! 3. **Serving interference** — the 512³ GEMM measured idle and again
//!    while closed-loop sampling load saturates the shard workers, so
//!    the GEMM-vs-shards core split shows up as a number instead of an
//!    anecdote.
//! 4. **End-to-end preprocessing** — [`ModelEntry::prepare`] (marginal
//!    kernel + Youla/proposal + spectral + tree) at `M ∈ {1k, 4k, 16k}`
//!    (quick mode stops at 4k), with the process-wide backend pinned per
//!    measurement — this is the registry path a deployment pays on every
//!    model registration.
//!
//! Results are printed as tables and written as `BENCH_linalg.json`
//! (override the path with `NDPP_BENCH_OUT`), the first entry of the
//! repo's `BENCH_*` trajectory.  CI runs quick mode, feeds the JSON
//! through `scripts/bench_gate.py` (which enforces the blocked-vs-naive,
//! simd-vs-blocked, and packed-vs-unpacked speedup floors on the 512³
//! row, the pool-vs-spawn floor on the panel sweep, and merges it into
//! `BENCH_trajectory.json`), and uploads both as artifacts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::bench::experiments::tablelike_kernel;
use crate::bench::runner::{BenchRunner, Table};
use crate::coordinator::registry::ModelEntry;
use crate::coordinator::{SampleRequest, SamplerKind, SamplingService, ServiceConfig};
use crate::linalg::backend::{self, Backend as _, BackendKind, SimdBackend};
use crate::linalg::Matrix;
use crate::rng::Xoshiro;
use crate::sampler::TreeConfig;
use crate::util::json::Json;
use crate::util::timer::fmt_secs;

/// Per-part rank for the preprocessing sweep (2K = 64 panel width).
const PREP_K: usize = 32;

/// Run the sweep; returns the JSON that was also written to `out_path`.
pub fn run(quick: bool, out_path: &str) -> Result<Json> {
    let runner = if quick {
        BenchRunner { warmup: 1, iters: 5, max_secs: 3.0 }
    } else {
        BenchRunner { warmup: 2, iters: 12, max_secs: 20.0 }
    };

    println!(
        "linalg_backends: {} mode, {} worker threads, simd ISA: {}",
        if quick { "quick" } else { "full" },
        backend::configured_threads(),
        backend::simd_isa().as_str()
    );

    // ---- GEMM shape sweep -------------------------------------------------
    let mut shapes: Vec<(usize, usize, usize)> = vec![
        (128, 128, 128),
        (256, 256, 256),
        (512, 512, 512),
        // skinny panel products: Z (M x 2K) against 2K x 2K inner matrices
        (4096, 64, 64),
    ];
    if !quick {
        shapes.push((1024, 1024, 1024));
        shapes.push((16384, 64, 64));
    }
    let (gemm_table, gemm_rows) = gemm_sweep(&runner, &shapes);
    println!("\n== GEMM by backend ==\n{}", gemm_table.render());

    // ---- pool vs spawn-per-call on the skinny panel sweep -----------------
    let mut pool_shapes: Vec<(usize, usize, usize)> = vec![(4096, 64, 64)];
    if !quick {
        pool_shapes.push((16384, 64, 64));
    }
    let (pool_table, pool_rows) = pool_sweep(&runner, &pool_shapes);
    println!("== pool vs spawn fan-out (simd backend) ==\n{}", pool_table.render());

    // ---- GEMM under serving load ------------------------------------------
    let interference = interference_case(&runner, quick);
    println!(
        "== 512^3 simd GEMM under serving load: idle {} vs loaded {} (x{:.2}) ==\n",
        fmt_secs(interference.f64_or("idle_s", 0.0)),
        fmt_secs(interference.f64_or("loaded_s", 0.0)),
        interference.f64_or("slowdown", 0.0),
    );

    // ---- end-to-end registry preprocessing --------------------------------
    let ms: Vec<usize> = if quick {
        vec![1024, 4096]
    } else {
        vec![1024, 4096, 16384]
    };
    let saved = backend::active_kind();
    let mut prep_table = Table::new(&["M", "naive", "blocked", "simd", "blk/naive", "simd/blk"]);
    let mut prep_rows: Vec<Json> = Vec::new();
    for &m in &ms {
        let mut rng = Xoshiro::seeded(m as u64);
        let kernel = tablelike_kernel(m, PREP_K, &mut rng);
        let mut means = Vec::new();
        for kind in BackendKind::ALL {
            backend::set_active(kind);
            let meas = runner.measure(kind.as_str(), || {
                let _ = ModelEntry::prepare("bench", kernel.clone(), TreeConfig::default());
            });
            means.push(meas.mean());
        }
        let (naive_s, blocked_s, simd_s) = (means[0], means[1], means[2]);
        let speedup = naive_s / blocked_s.max(1e-12);
        let simd_vs_blocked = blocked_s / simd_s.max(1e-12);
        prep_table.row(vec![
            format!("{m}"),
            fmt_secs(naive_s),
            fmt_secs(blocked_s),
            fmt_secs(simd_s),
            format!("x{speedup:.2}"),
            format!("x{simd_vs_blocked:.2}"),
        ]);
        prep_rows.push(
            Json::obj()
                .with("m", m)
                .with("k", PREP_K)
                .with("naive_s", naive_s)
                .with("blocked_s", blocked_s)
                .with("simd_s", simd_s)
                .with("speedup", speedup)
                .with("simd_vs_blocked", simd_vs_blocked),
        );
    }
    backend::set_active(saved);
    println!(
        "== registry preprocessing (marginal + proposal + spectral + tree, K={PREP_K}) ==\n{}",
        prep_table.render()
    );

    let budget = backend::thread_budget();
    let json = Json::obj()
        .with("bench", "linalg_backends")
        .with("quick", quick)
        .with("threads", backend::configured_threads())
        .with(
            "budget",
            Json::obj()
                .with("cores", budget.cores)
                .with("backend_threads", budget.backend)
                .with("pool_workers", budget.pool_workers)
                .with("default_shards", budget.shards)
                .with("explicit", budget.explicit),
        )
        .with("isa", backend::simd_isa().as_str())
        .with("gemm", Json::Arr(gemm_rows))
        .with("pool", Json::Arr(pool_rows))
        .with("interference", interference)
        .with("preprocess", Json::Arr(prep_rows));
    std::fs::write(out_path, json.to_string_pretty())?;
    println!("(written to {out_path})");
    Ok(json)
}

/// Measure `gemm` on each backend for every shape, plus the simd
/// backend's unpacked reference walk so the packed-panel win is recorded
/// per shape.  Backends are invoked as instances — the process-global
/// selection is untouched, so this part is safe to exercise from unit
/// tests running next to other tests.
fn gemm_sweep(runner: &BenchRunner, shapes: &[(usize, usize, usize)]) -> (Table, Vec<Json>) {
    let mut table = Table::new(&[
        "shape (m x k x n)",
        "naive",
        "blocked",
        "simd",
        "blk/naive",
        "simd/blk",
        "packed/unpacked",
    ]);
    let mut rows: Vec<Json> = Vec::new();
    let simd = SimdBackend::detect();
    for &(m, k, n) in shapes {
        let mut rng = Xoshiro::seeded((m * 31 + n) as u64);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut means = Vec::new();
        for kind in BackendKind::ALL {
            let be = kind.instance();
            let meas = runner.measure(kind.as_str(), || {
                let _ = be.gemm(&a, &b);
            });
            means.push(meas.mean());
        }
        let unpacked_s = runner
            .measure("simd_unpacked", || {
                let _ = simd.gemm_unpacked(&a, &b);
            })
            .mean();
        let (naive_s, blocked_s, simd_s) = (means[0], means[1], means[2]);
        let speedup = naive_s / blocked_s.max(1e-12);
        let simd_vs_blocked = blocked_s / simd_s.max(1e-12);
        let packed_vs_unpacked = unpacked_s / simd_s.max(1e-12);
        table.row(vec![
            format!("{m} x {k} x {n}"),
            fmt_secs(naive_s),
            fmt_secs(blocked_s),
            fmt_secs(simd_s),
            format!("x{speedup:.2}"),
            format!("x{simd_vs_blocked:.2}"),
            format!("x{packed_vs_unpacked:.2}"),
        ]);
        rows.push(
            Json::obj()
                .with("m", m)
                .with("k", k)
                .with("n", n)
                .with("naive_s", naive_s)
                .with("blocked_s", blocked_s)
                .with("simd_s", simd_s)
                .with("simd_unpacked_s", unpacked_s)
                .with("speedup", speedup)
                .with("simd_vs_blocked", simd_vs_blocked)
                .with("packed_vs_unpacked", packed_vs_unpacked),
        );
    }
    (table, rows)
}

/// Measure the simd GEMM with its band fan-out on the persistent pool
/// against the same bands on spawn-per-call `std::thread::scope`
/// threads.  The skinny `M x 2K` panel shapes are where handoff cost
/// matters: the product is over the fan-out floor but each band is
/// small, so per-call thread spawn used to eat the parallel win.
fn pool_sweep(runner: &BenchRunner, shapes: &[(usize, usize, usize)]) -> (Table, Vec<Json>) {
    let mut table = Table::new(&["shape (m x k x n)", "pool", "spawn", "pool/spawn"]);
    let mut rows: Vec<Json> = Vec::new();
    let simd = SimdBackend::detect();
    for &(m, k, n) in shapes {
        let mut rng = Xoshiro::seeded((m * 17 + n) as u64);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let pool_s = runner
            .measure("pool", || {
                let _ = simd.gemm(&a, &b);
            })
            .mean();
        let spawn_s = runner
            .measure("spawn", || {
                let _ = simd.gemm_spawn_fanout(&a, &b);
            })
            .mean();
        let pool_vs_spawn = spawn_s / pool_s.max(1e-12);
        table.row(vec![
            format!("{m} x {k} x {n}"),
            fmt_secs(pool_s),
            fmt_secs(spawn_s),
            format!("x{pool_vs_spawn:.2}"),
        ]);
        rows.push(
            Json::obj()
                .with("m", m)
                .with("k", k)
                .with("n", n)
                .with("pool_s", pool_s)
                .with("spawn_s", spawn_s)
                .with("pool_vs_spawn", pool_vs_spawn),
        );
    }
    (table, rows)
}

/// Measure the 512³ simd GEMM idle, then again while closed-loop
/// sampling clients keep every shard worker of an in-process
/// [`SamplingService`] busy — the contention a deployment sees when
/// model registration (GEMM-heavy) lands on a box already serving
/// traffic.  Returns `{idle_s, loaded_s, slowdown}`.
fn interference_case(runner: &BenchRunner, quick: bool) -> Json {
    let simd = SimdBackend::detect();
    let dim = 512;
    let mut rng = Xoshiro::seeded(dim as u64);
    let a = Matrix::randn(dim, dim, 1.0, &mut rng);
    let b = Matrix::randn(dim, dim, 1.0, &mut rng);
    let idle_s = runner
        .measure("gemm idle", || {
            let _ = simd.gemm(&a, &b);
        })
        .mean();

    let (m, k) = if quick { (512, 8) } else { (2048, 16) };
    let svc = Arc::new(SamplingService::new(ServiceConfig::default()));
    let mut krng = Xoshiro::seeded(11);
    svc.register("interf", tablelike_kernel(m, k, &mut krng));
    let stop = Arc::new(AtomicBool::new(false));
    let loaded_s = std::thread::scope(|scope| {
        // one closed-loop client per shard keeps the workers saturated
        // while the foreground thread re-runs the GEMM measurement
        for c in 0..svc.shards() {
            let svc = Arc::clone(&svc);
            let stop = Arc::clone(&stop);
            scope.spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let _ = svc.sample(SampleRequest {
                        model: "interf".into(),
                        n: 2,
                        seed: Some(((c as u64) << 32) | i),
                        kind: SamplerKind::Cholesky,
                        deadline: None,
                        given: Vec::new(),
                        chain: false,
                        trace: false,
                    });
                    i += 1;
                }
            });
        }
        let loaded = runner
            .measure("gemm loaded", || {
                let _ = simd.gemm(&a, &b);
            })
            .mean();
        stop.store(true, Ordering::Relaxed);
        loaded
    });
    Json::obj()
        .with("idle_s", idle_s)
        .with("loaded_s", loaded_s)
        .with("slowdown", loaded_s / idle_s.max(1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the full `run()` (which pins backends process-wide for the
    // preprocessing sweep) is deliberately NOT exercised here — flipping
    // the global backend would race with other lib tests in this binary.
    // It runs in its own process via `cargo bench --bench linalg_backends`
    // (quick mode in CI).

    #[test]
    fn gemm_sweep_produces_timings() {
        let runner = BenchRunner { warmup: 1, iters: 3, max_secs: 0.5 };
        let (table, rows) = gemm_sweep(&runner, &[(24, 16, 24), (33, 9, 7)]);
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert!(row.f64_or("naive_s", -1.0) > 0.0);
            assert!(row.f64_or("blocked_s", -1.0) > 0.0);
            assert!(row.f64_or("simd_s", -1.0) > 0.0);
            assert!(row.f64_or("simd_unpacked_s", -1.0) > 0.0);
            assert!(row.f64_or("speedup", -1.0) > 0.0);
            assert!(row.f64_or("simd_vs_blocked", -1.0) > 0.0);
            assert!(row.f64_or("packed_vs_unpacked", -1.0) > 0.0);
        }
        assert!(table.render().contains("24 x 16 x 24"));
    }

    #[test]
    fn pool_sweep_produces_timings() {
        let runner = BenchRunner { warmup: 1, iters: 3, max_secs: 0.5 };
        let (table, rows) = pool_sweep(&runner, &[(96, 16, 16)]);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].f64_or("pool_s", -1.0) > 0.0);
        assert!(rows[0].f64_or("spawn_s", -1.0) > 0.0);
        assert!(rows[0].f64_or("pool_vs_spawn", -1.0) > 0.0);
        assert!(table.render().contains("96 x 16 x 16"));
    }
}
