//! `cargo bench --bench linalg_backends` — the compute-backend sweep
//! (three-way: naive / blocked / simd, with the detected SIMD ISA
//! recorded in the JSON).
//!
//! Two measurement families, each run under every [`BackendKind`]:
//!
//! 1. **GEMM shapes** — square products at 128/256/512 (plus 1024 in full
//!    mode) and the skinny `M x 2K` panel shapes the samplers actually
//!    produce.  Backends are invoked directly (no global flipping), so the
//!    comparison is apples-to-apples on identical inputs.
//! 2. **End-to-end preprocessing** — [`ModelEntry::prepare`] (marginal
//!    kernel + Youla/proposal + spectral + tree) at `M ∈ {1k, 4k, 16k}`
//!    (quick mode stops at 4k), with the process-wide backend pinned per
//!    measurement — this is the registry path a deployment pays on every
//!    model registration.
//!
//! Results are printed as tables and written as `BENCH_linalg.json`
//! (override the path with `NDPP_BENCH_OUT`), the first entry of the
//! repo's `BENCH_*` trajectory.  CI runs quick mode, feeds the JSON
//! through `scripts/bench_gate.py` (which enforces the blocked-vs-naive
//! and simd-vs-blocked speedup floors on the 512³ row and merges it into
//! `BENCH_trajectory.json`), and uploads both as artifacts.

use anyhow::Result;

use crate::bench::experiments::tablelike_kernel;
use crate::bench::runner::{BenchRunner, Table};
use crate::coordinator::registry::ModelEntry;
use crate::linalg::backend::{self, Backend as _, BackendKind};
use crate::linalg::Matrix;
use crate::rng::Xoshiro;
use crate::sampler::TreeConfig;
use crate::util::json::Json;
use crate::util::timer::fmt_secs;

/// Per-part rank for the preprocessing sweep (2K = 64 panel width).
const PREP_K: usize = 32;

/// Run the sweep; returns the JSON that was also written to `out_path`.
pub fn run(quick: bool, out_path: &str) -> Result<Json> {
    let runner = if quick {
        BenchRunner { warmup: 1, iters: 5, max_secs: 3.0 }
    } else {
        BenchRunner { warmup: 2, iters: 12, max_secs: 20.0 }
    };

    println!(
        "linalg_backends: {} mode, {} worker threads, simd ISA: {}",
        if quick { "quick" } else { "full" },
        backend::configured_threads(),
        backend::simd_isa().as_str()
    );

    // ---- GEMM shape sweep -------------------------------------------------
    let mut shapes: Vec<(usize, usize, usize)> = vec![
        (128, 128, 128),
        (256, 256, 256),
        (512, 512, 512),
        // skinny panel products: Z (M x 2K) against 2K x 2K inner matrices
        (4096, 64, 64),
    ];
    if !quick {
        shapes.push((1024, 1024, 1024));
        shapes.push((16384, 64, 64));
    }
    let (gemm_table, gemm_rows) = gemm_sweep(&runner, &shapes);
    println!("\n== GEMM by backend ==\n{}", gemm_table.render());

    // ---- end-to-end registry preprocessing --------------------------------
    let ms: Vec<usize> = if quick {
        vec![1024, 4096]
    } else {
        vec![1024, 4096, 16384]
    };
    let saved = backend::active_kind();
    let mut prep_table = Table::new(&["M", "naive", "blocked", "simd", "blk/naive", "simd/blk"]);
    let mut prep_rows: Vec<Json> = Vec::new();
    for &m in &ms {
        let mut rng = Xoshiro::seeded(m as u64);
        let kernel = tablelike_kernel(m, PREP_K, &mut rng);
        let mut means = Vec::new();
        for kind in BackendKind::ALL {
            backend::set_active(kind);
            let meas = runner.measure(kind.as_str(), || {
                let _ = ModelEntry::prepare("bench", kernel.clone(), TreeConfig::default());
            });
            means.push(meas.mean());
        }
        let (naive_s, blocked_s, simd_s) = (means[0], means[1], means[2]);
        let speedup = naive_s / blocked_s.max(1e-12);
        let simd_vs_blocked = blocked_s / simd_s.max(1e-12);
        prep_table.row(vec![
            format!("{m}"),
            fmt_secs(naive_s),
            fmt_secs(blocked_s),
            fmt_secs(simd_s),
            format!("x{speedup:.2}"),
            format!("x{simd_vs_blocked:.2}"),
        ]);
        prep_rows.push(
            Json::obj()
                .with("m", m)
                .with("k", PREP_K)
                .with("naive_s", naive_s)
                .with("blocked_s", blocked_s)
                .with("simd_s", simd_s)
                .with("speedup", speedup)
                .with("simd_vs_blocked", simd_vs_blocked),
        );
    }
    backend::set_active(saved);
    println!(
        "== registry preprocessing (marginal + proposal + spectral + tree, K={PREP_K}) ==\n{}",
        prep_table.render()
    );

    let json = Json::obj()
        .with("bench", "linalg_backends")
        .with("quick", quick)
        .with("threads", backend::configured_threads())
        .with("isa", backend::simd_isa().as_str())
        .with("gemm", Json::Arr(gemm_rows))
        .with("preprocess", Json::Arr(prep_rows));
    std::fs::write(out_path, json.to_string_pretty())?;
    println!("(written to {out_path})");
    Ok(json)
}

/// Measure `gemm` on each backend for every shape.  Backends are invoked
/// as instances — the process-global selection is untouched, so this part
/// is safe to exercise from unit tests running next to other tests.
fn gemm_sweep(runner: &BenchRunner, shapes: &[(usize, usize, usize)]) -> (Table, Vec<Json>) {
    let mut table =
        Table::new(&["shape (m x k x n)", "naive", "blocked", "simd", "blk/naive", "simd/blk"]);
    let mut rows: Vec<Json> = Vec::new();
    for &(m, k, n) in shapes {
        let mut rng = Xoshiro::seeded((m * 31 + n) as u64);
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let mut means = Vec::new();
        for kind in BackendKind::ALL {
            let be = kind.instance();
            let meas = runner.measure(kind.as_str(), || {
                let _ = be.gemm(&a, &b);
            });
            means.push(meas.mean());
        }
        let (naive_s, blocked_s, simd_s) = (means[0], means[1], means[2]);
        let speedup = naive_s / blocked_s.max(1e-12);
        let simd_vs_blocked = blocked_s / simd_s.max(1e-12);
        table.row(vec![
            format!("{m} x {k} x {n}"),
            fmt_secs(naive_s),
            fmt_secs(blocked_s),
            fmt_secs(simd_s),
            format!("x{speedup:.2}"),
            format!("x{simd_vs_blocked:.2}"),
        ]);
        rows.push(
            Json::obj()
                .with("m", m)
                .with("k", k)
                .with("n", n)
                .with("naive_s", naive_s)
                .with("blocked_s", blocked_s)
                .with("simd_s", simd_s)
                .with("speedup", speedup)
                .with("simd_vs_blocked", simd_vs_blocked),
        );
    }
    (table, rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the full `run()` (which pins backends process-wide for the
    // preprocessing sweep) is deliberately NOT exercised here — flipping
    // the global backend would race with other lib tests in this binary.
    // It runs in its own process via `cargo bench --bench linalg_backends`
    // (quick mode in CI).

    #[test]
    fn gemm_sweep_produces_timings() {
        let runner = BenchRunner { warmup: 1, iters: 3, max_secs: 0.5 };
        let (table, rows) = gemm_sweep(&runner, &[(24, 16, 24), (33, 9, 7)]);
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert!(row.f64_or("naive_s", -1.0) > 0.0);
            assert!(row.f64_or("blocked_s", -1.0) > 0.0);
            assert!(row.f64_or("simd_s", -1.0) > 0.0);
            assert!(row.f64_or("speedup", -1.0) > 0.0);
            assert!(row.f64_or("simd_vs_blocked", -1.0) > 0.0);
        }
        assert!(table.render().contains("24 x 16 x 24"));
    }
}
