//! `cargo bench --bench serving` — closed-loop serving throughput/latency
//! sweep.
//!
//! Drives the sharded [`SamplingService`] in-process (no TCP, so the
//! numbers isolate the pipeline: shard queues, admission control, batch
//! coalescing, Prepared/Scratch reuse) with a closed loop per client:
//! each of `1 / 4 / 16` concurrent clients issues synchronous
//! `sample(model, n, seed)` requests back to back, for every algorithm in
//! `cholesky / rejection / mcmc`, plus a `given`-bearing conditional
//! sweep (`1 / 4` clients, every request paying per-request Schur
//! conditioning) and a **hot-basket sweep**: Zipf-repeated baskets driven
//! through identical request schedules with the conditioning cache off
//! and on, so the cache's effect on conditional throughput (and its
//! hit/eviction behavior) lands in the benchmark record, and a **mixing
//! sweep** (`mcmc_mixing[]`): burn-in steps-to-TV against an enumerated
//! sigma~1 nonorthogonal kernel plus steered closed-loop throughput, per
//! proposal kind (uniform oracle vs tree-driven).  Reports per-config
//! request throughput, sample throughput, and latency percentiles, and
//! writes `BENCH_serving.json` (override the path with `NDPP_BENCH_OUT`;
//! `sweep[]` + `conditional[]` + `cache[]` + `mcmc_mixing[]` +
//! `lifecycle.eval[]` + `tracing[]` rows) — the serving entry of the
//! repo's `BENCH_*` trajectory, uploaded as a CI artifact next to
//! `BENCH_linalg.json`.  The **tracing sweep** drives one identical
//! closed-loop schedule with `trace: false` and `trace: true`, so the
//! request-lifecycle tracing overhead is measured on every run.
//! `scripts/bench_gate.py` fails the build if the `cache[]` column goes
//! missing, the warm (cache-on) config falls below the cold one, the
//! `mcmc_mixing[]` column goes missing, any steered config serves zero
//! throughput, the tree proposal needs more burn-in than the uniform
//! oracle, the `lifecycle.eval[]` promotion-gate column goes missing, a
//! must-promote control fails its gate, any recorded gate decision is
//! inconsistent with its own MPR/AUC scores, the `tracing[]` column goes
//! missing or serves zero throughput, or the traced config falls below
//! 0.90× the untraced throughput.

use std::sync::Arc;

use anyhow::Result;

use crate::bench::experiments::{nonorthogonal_kernel, tablelike_kernel};
use crate::bench::runner::Table;
use crate::coordinator::{SampleRequest, SamplerKind, SamplingService, ServiceConfig, Trace};
use crate::ndpp::{probability, Proposal};
use crate::rng::Xoshiro;
use crate::sampler::{
    McmcConfig, ProposalKind, SampleTree, Sampler as _, TreeConfig, VariableMcmcSampler,
};
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::testing::{empirical_from, tv};
use crate::util::timer::fmt_secs;
use crate::util::Timer;

/// Samples per request (coalescing and scratch reuse amortize across
/// these, like a real recommendation batch).
const SAMPLES_PER_REQUEST: usize = 4;

/// Run the sweep; returns the JSON that was also written to `out_path`.
pub fn run(quick: bool, out_path: &str) -> Result<Json> {
    let (m, k, iters_per_client) = if quick { (512, 8, 30) } else { (4096, 32, 150) };

    let svc = Arc::new(SamplingService::new(ServiceConfig::default()));
    let mut rng = Xoshiro::seeded(7);
    svc.register("bench", tablelike_kernel(m, k, &mut rng));
    println!(
        "serving bench: {} mode, M={m}, 2K={}, {} shard workers, {} samples/request",
        if quick { "quick" } else { "full" },
        2 * k,
        svc.shards(),
        SAMPLES_PER_REQUEST
    );

    let algos = [SamplerKind::Cholesky, SamplerKind::Rejection, SamplerKind::Mcmc];
    let client_counts = [1usize, 4, 16];

    let mut table =
        Table::new(&["algo", "clients", "given", "req/s", "samples/s", "p50", "p95"]);
    let mut rows: Vec<Json> = Vec::new();
    for kind in algos {
        for &clients in &client_counts {
            // MCMC restarts a full burn-in per sample; trim its iteration
            // count so the full sweep stays CI-sized
            let iters = if kind == SamplerKind::Mcmc {
                (iters_per_client / 3).max(5)
            } else {
                iters_per_client
            };
            let (wall, latencies) = closed_loop(&svc, kind, clients, iters, &[]);
            let requests = (clients * iters) as f64;
            let req_s = requests / wall;
            let samples_s = req_s * SAMPLES_PER_REQUEST as f64;
            let lat = Summary::of(&latencies);
            table.row(vec![
                kind.as_str().to_string(),
                format!("{clients}"),
                "-".to_string(),
                format!("{req_s:.0}"),
                format!("{samples_s:.0}"),
                fmt_secs(lat.p50),
                fmt_secs(lat.p95),
            ]);
            rows.push(
                Json::obj()
                    .with("algo", kind.as_str())
                    .with("clients", clients)
                    .with("requests", requests)
                    .with("wall_s", wall)
                    .with("requests_per_s", req_s)
                    .with("samples_per_s", samples_s)
                    .with("latency_p50_s", lat.p50)
                    .with("latency_p95_s", lat.p95)
                    .with("latency_mean_s", lat.mean),
            );
        }
    }

    // conditional (basket-completion) sweep: every request carries a
    // `given` basket, so each one pays the per-request Schur conditioning
    // on top of sampling — the column bench_gate.py watches for wedges in
    // the conditional dispatch (serving.conditional[])
    let given: Vec<usize> = vec![1, 7, 2 * k + 3];
    let cond_clients = [1usize, 4];
    let mut cond_rows: Vec<Json> = Vec::new();
    for kind in algos {
        for &clients in &cond_clients {
            let iters = if kind == SamplerKind::Mcmc {
                (iters_per_client / 3).max(5)
            } else {
                iters_per_client
            };
            let (wall, latencies) = closed_loop(&svc, kind, clients, iters, &given);
            let requests = (clients * iters) as f64;
            let req_s = requests / wall;
            let lat = Summary::of(&latencies);
            table.row(vec![
                kind.as_str().to_string(),
                format!("{clients}"),
                format!("{}", given.len()),
                format!("{req_s:.0}"),
                format!("{:.0}", req_s * SAMPLES_PER_REQUEST as f64),
                fmt_secs(lat.p50),
                fmt_secs(lat.p95),
            ]);
            cond_rows.push(
                Json::obj()
                    .with("algo", kind.as_str())
                    .with("clients", clients)
                    .with("given_len", given.len())
                    .with("requests", requests)
                    .with("wall_s", wall)
                    .with("requests_per_s", req_s)
                    .with("samples_per_s", req_s * SAMPLES_PER_REQUEST as f64)
                    .with("latency_p50_s", lat.p50)
                    .with("latency_p95_s", lat.p95)
                    .with("latency_mean_s", lat.mean),
            );
        }
    }
    println!("\n== closed-loop serving sweep (M={m}, 2K={}) ==\n{}", 2 * k, table.render());

    let cache_rows = hot_basket_sweep(quick)?;
    let mixing_rows = mcmc_mixing_sweep(quick)?;
    let lifecycle = lifecycle_sweep(quick)?;
    let tracing_rows = tracing_sweep(quick)?;

    let json = Json::obj()
        .with("bench", "serving")
        .with("quick", quick)
        .with("m", m)
        .with("k", k)
        .with("shards", svc.shards())
        .with("samples_per_request", SAMPLES_PER_REQUEST)
        .with("sweep", Json::Arr(rows))
        .with("conditional", Json::Arr(cond_rows))
        .with("cache", Json::Arr(cache_rows))
        .with("mcmc_mixing", Json::Arr(mixing_rows))
        .with("lifecycle", lifecycle)
        .with("tracing", Json::Arr(tracing_rows));
    std::fs::write(out_path, json.to_string_pretty())?;
    println!("(written to {out_path})");
    Ok(json)
}

/// Zipf-repeated hot-basket schedule, replayed against a cache-off and a
/// cache-on deployment of the same model.  Conditional cholesky requests
/// are dominated by the per-request conditioning build at this rank, so
/// the warm-hit win (and the LRU's hit/miss/eviction behavior) is
/// directly visible in requests/s.  The schedule — seeds, baskets, and
/// client interleaving — is identical across configs; only the cache
/// budget differs.
fn hot_basket_sweep(quick: bool) -> Result<Vec<Json>> {
    let (m, k, requests_per_client) = if quick { (512, 24, 40) } else { (2048, 32, 120) };
    let clients = 4usize;
    // a pool of distinct baskets drawn Zipf-style: basket b gets weight
    // 1/(b+1), so a handful of baskets take most of the traffic — the
    // shape a recommender's "popular cart" workload has
    let pool: Vec<Vec<usize>> = (0..16).map(|b| vec![3 * b + 1, 3 * b + 2]).collect();
    let weights: Vec<f64> = (0..pool.len()).map(|b| 1.0 / (b + 1) as f64).collect();
    let mut sched_rng = Xoshiro::seeded(99);
    let schedule: Vec<Vec<usize>> = (0..clients * requests_per_client)
        .map(|_| pool[sched_rng.weighted(&weights)].clone())
        .collect();

    let mut table = Table::new(&["cache", "clients", "req/s", "hits", "misses", "evict", "bytes"]);
    let mut rows: Vec<Json> = Vec::new();
    for (config, budget) in [("off", 0usize), ("on", 64 << 20)] {
        let svc = Arc::new(SamplingService::new(ServiceConfig {
            shards: 4,
            conditioning_cache_bytes: budget,
            ..Default::default()
        }));
        let mut rng = Xoshiro::seeded(7);
        svc.register("hot", tablelike_kernel(m, k, &mut rng));
        let wall = Timer::start();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let svc = Arc::clone(&svc);
                let lo = c * requests_per_client;
                let slice: Vec<Vec<usize>> = schedule[lo..lo + requests_per_client].to_vec();
                scope.spawn(move || {
                    for (i, given) in slice.into_iter().enumerate() {
                        svc.sample(SampleRequest {
                            model: "hot".into(),
                            n: SAMPLES_PER_REQUEST,
                            seed: Some(((c as u64) << 32) | i as u64),
                            kind: SamplerKind::Cholesky,
                            deadline: None,
                            given,
                            chain: false,
                            trace: false,
                        })
                        .expect("hot-basket request failed");
                    }
                });
            }
        });
        let wall = wall.secs();
        let total = (clients * requests_per_client) as f64;
        let req_s = total / wall;
        let stats = svc.conditioning_cache().stats();
        table.row(vec![
            config.to_string(),
            format!("{clients}"),
            format!("{req_s:.0}"),
            format!("{}", stats.hits),
            format!("{}", stats.misses),
            format!("{}", stats.evictions),
            format!("{}", stats.bytes),
        ]);
        rows.push(
            Json::obj()
                .with("config", config)
                .with("clients", clients)
                .with("requests", total)
                .with("wall_s", wall)
                .with("requests_per_s", req_s)
                .with("hits", stats.hits)
                .with("misses", stats.misses)
                .with("evictions", stats.evictions)
                .with("bytes", stats.bytes),
        );
    }
    println!("\n== hot-basket conditioning cache (M={m}, 2K={}) ==\n{}", 2 * k, table.render());
    Ok(rows)
}

/// Mixing-time sweep for the up/down/swap chain, tree vs uniform proposal
/// (`serving.mcmc_mixing[]`).  Two measurements per proposal kind:
///
/// 1. **Steps-to-TV** on an enumerable sigma~1 nonorthogonal kernel — the
///    regime rejection can't touch, where MCMC is the only sampler left.
///    The chain is restarted with a fixed burn-in budget from a
///    power-of-two grid (adaptive burn-in off, so every sample pays
///    exactly `g` steps) and the empirical subset distribution is
///    compared against `probability::enumerate_probs` in total variation;
///    `steps_to_tv` is the first grid value under the target.
/// 2. **Steered closed-loop throughput**: a `steer_threshold = 0`
///    deployment forces every `auto` basket request through the
///    conditional variable-size chain; requests/s and the chain's
///    measured acceptance rate land in the row.
///
/// `scripts/bench_gate.py` fails the build if the column is missing, any
/// config's throughput is zero, or the tree proposal needs *more* burn-in
/// steps than the uniform oracle it replaces.
fn mcmc_mixing_sweep(quick: bool) -> Result<Vec<Json>> {
    // small enough to enumerate (2^7 states), sigma ~ 1 so rejection's
    // U ~ 2^{K/2} bound is gone and steering always picks the chain
    let (mix_m, mix_k, chains) = if quick { (7usize, 2usize, 4_000usize) } else { (7, 2, 12_000) };
    let grid: &[usize] = if quick { &[8, 16, 32, 64, 128] } else { &[8, 16, 32, 64, 128, 256] };
    const TV_TARGET: f64 = 0.12;

    let mut krng = Xoshiro::seeded(17);
    let kernel = nonorthogonal_kernel(mix_m, mix_k, 1.0, &mut krng);
    let want = probability::enumerate_probs(&kernel);
    let proposal = Proposal::build(&kernel);
    let sample_tree = SampleTree::build(&proposal.spectral(), TreeConfig { leaf_size: 2 });
    let base = McmcConfig::for_kernel(&kernel);

    // serving-sized kernel for the steered closed loop
    let (srv_m, srv_k, iters) = if quick { (256usize, 8usize, 10usize) } else { (1024, 16, 30) };
    let clients = 4usize;

    let mut table =
        Table::new(&["proposal", "steps_to_tv", "final_tv", "acceptance", "steered req/s"]);
    let mut rows: Vec<Json> = Vec::new();
    for kind in [ProposalKind::Uniform, ProposalKind::Tree] {
        // --- mixing: burn-in grid against the enumerated law ---
        let mut config = base;
        config.proposal = kind;
        config.adaptive_burn_in = false;
        let mut steps_to_tv = *grid.last().expect("grid nonempty");
        let mut final_tv = f64::INFINITY;
        let mut acceptance = 0.0;
        let mut hit = false;
        for &g in grid {
            let mut cfg = config;
            cfg.burn_in = g;
            let mut rng = Xoshiro::seeded(18);
            let mut chain = VariableMcmcSampler::new(&kernel, cfg).with_tree(&sample_tree);
            let freq = empirical_from(mix_m, chains, &mut rng, |r| chain.sample(r));
            final_tv = tv(&freq, &want);
            acceptance = chain.acceptance_rate();
            if !hit && final_tv <= TV_TARGET {
                steps_to_tv = g;
                hit = true;
            }
        }

        // --- steered closed loop: threshold 0 routes every auto request
        // with a basket through the conditional variable-size chain ---
        let svc = Arc::new(SamplingService::new(ServiceConfig {
            shards: 4,
            steer_threshold: 0.0,
            mcmc_proposal: kind,
            ..Default::default()
        }));
        let mut rng = Xoshiro::seeded(19);
        svc.register("steer", nonorthogonal_kernel(srv_m, srv_k, 1.0, &mut rng));
        let wall = Timer::start();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let svc = Arc::clone(&svc);
                scope.spawn(move || {
                    for i in 0..iters {
                        svc.sample(SampleRequest {
                            model: "steer".into(),
                            n: SAMPLES_PER_REQUEST,
                            seed: Some(((c as u64) << 32) | i as u64),
                            kind: SamplerKind::Auto,
                            given: vec![1, 7],
                            ..Default::default()
                        })
                        .expect("steered request failed");
                    }
                });
            }
        });
        let wall = wall.secs();
        let req_s = (clients * iters) as f64 / wall;
        let (srv_reqs, srv_steps, srv_accepts) = svc.metrics().mcmc_counts("steer", kind.as_str());
        assert_eq!(srv_reqs as usize, clients * iters, "steering missed requests");

        table.row(vec![
            kind.as_str().to_string(),
            format!("{steps_to_tv}{}", if hit { "" } else { "+" }),
            format!("{final_tv:.3}"),
            format!("{acceptance:.3}"),
            format!("{req_s:.0}"),
        ]);
        rows.push(
            Json::obj()
                .with("proposal", kind.as_str())
                .with("m", mix_m)
                .with("chains", chains)
                .with("tv_target", TV_TARGET)
                .with("steps_to_tv", steps_to_tv)
                .with("converged", hit)
                .with("final_tv", final_tv)
                .with("acceptance", acceptance)
                .with("steered_m", srv_m)
                .with("steered_clients", clients)
                .with("steered_requests", clients * iters)
                .with("steered_wall_s", wall)
                .with("steered_requests_per_s", req_s)
                .with("steered_chain_steps", srv_steps)
                .with("steered_chain_accepts", srv_accepts),
        );
    }
    println!("\n== mcmc mixing: tree vs uniform proposal (M={mix_m}, sigma=1) ==\n{}", table.render());
    Ok(rows)
}

/// Promotion-gate sweep (`serving.lifecycle.eval[]`): run the train →
/// canary → gated-promote cycle against a live deployment and record the
/// MPR/AUC scores the gate saw plus its decision, one row per scenario:
///
/// 1. **identity** — the candidate is the live kernel re-registered, so
///    both sides score identically and the non-regression gate *must*
///    promote (`must_promote: true` — `scripts/bench_gate.py` fails the
///    build if it didn't).
/// 2. **trained** — a [`crate::learn::NativeTrainer`] candidate learned
///    from the same basket distribution the holdout was drawn from; the
///    gate decision is recorded and checked for *consistency* (promoted
///    iff the candidate was not worse on either metric), whichever way
///    the scores land.
fn lifecycle_sweep(quick: bool) -> Result<Json> {
    use crate::data::synthetic::{generate_baskets, BasketGenConfig};
    use crate::learn::{NativeTrainer, TrainConfig};

    let (m, k, steps) = if quick { (48usize, 4usize, 30usize) } else { (96, 8, 80) };
    let gen = BasketGenConfig {
        m,
        n_baskets: if quick { 240 } else { 600 },
        mean_size: 4.0,
        ..Default::default()
    };
    let mut drng = Xoshiro::seeded(31);
    let ds = generate_baskets(&gen, &mut drng);
    let mut ds = ds;
    ds.trim(2 * k);
    let mut srng = Xoshiro::seeded(32);
    let split = ds.split(20, 60, &mut srng);
    let mu = ds.item_frequencies();

    let svc = Arc::new(SamplingService::new(ServiceConfig {
        shards: 2,
        ..Default::default()
    }));
    let mut krng = Xoshiro::seeded(33);
    let live_kernel = crate::ndpp::NdppKernel::random_ondpp(m, k, &mut krng);
    svc.register("lifecycle", live_kernel.clone());

    let eps = 1e-9;
    let mut table = Table::new(&["scenario", "cand MPR", "cand AUC", "live MPR", "live AUC", "promoted"]);
    let mut rows: Vec<Json> = Vec::new();
    let mut gate = |scenario: &str,
                    candidate: crate::ndpp::NdppKernel,
                    must_promote: bool|
     -> Result<()> {
        let version = svc.register_candidate("lifecycle", candidate)?;
        let (live_v, _, _) = svc.registry().alias_state("lifecycle")?;
        let outcome = svc.promote_gated("lifecycle", Some(version), &split.test, 41);
        let (promoted, cand_scores, live_scores) = match &outcome {
            Ok((_, c, l)) => (true, *c, *l),
            Err(_) => {
                // scores are reproducible: re-evaluate both sides with the
                // gate's seed to record what it compared
                let c = svc.evaluate(&format!("lifecycle@{version}"), &split.test, 41)?;
                let l = svc.evaluate(&format!("lifecycle@{live_v}"), &split.test, 41)?;
                (false, c, l)
            }
        };
        table.row(vec![
            scenario.to_string(),
            format!("{:.2}", cand_scores.0),
            format!("{:.4}", cand_scores.1),
            format!("{:.2}", live_scores.0),
            format!("{:.4}", live_scores.1),
            format!("{promoted}"),
        ]);
        rows.push(
            Json::obj()
                .with("scenario", scenario)
                .with("candidate_version", version)
                .with("live_version", live_v)
                .with("candidate_mpr", cand_scores.0)
                .with("candidate_auc", cand_scores.1)
                .with("live_mpr", live_scores.0)
                .with("live_auc", live_scores.1)
                .with("eps", eps)
                .with("promoted", promoted)
                .with("must_promote", must_promote),
        );
        Ok(())
    };

    // 1: identical candidate — equal scores, the gate must pass
    gate("identity", live_kernel, true)?;
    // 2: a natively trained candidate against whatever is live now
    let tc = TrainConfig {
        k,
        batch_size: 24,
        kmax: 2 * k,
        steps,
        seed: 34,
        ..Default::default()
    };
    let trained = NativeTrainer::new(m, split.train.clone(), mu, tc)?.run(|_, _| {})?;
    gate("trained", trained.kernel, false)?;

    println!("\n== lifecycle promotion gate (M={m}, 2K={}) ==\n{}", 2 * k, table.render());
    Ok(Json::obj().with("eval", Json::Arr(rows)))
}

/// Tracing-overhead sweep (`serving.tracing[]`): one identical
/// closed-loop cholesky schedule — same seeds, same client interleaving —
/// driven against fresh deployments of the same kernel with `trace:
/// false` and `trace: true`.  Span stamping and per-stage histogram
/// folding are always on (they are what the metrics op reports), so both
/// configs pay them; the traced config additionally renders every
/// response's span timeline to its JSON wire payload — the marginal work
/// the opt-in `trace` field buys a dashboard-tailing client.
/// `scripts/bench_gate.py` fails the build if the column is missing,
/// either config serves zero throughput, or the traced config falls
/// below 0.90x the untraced throughput.
fn tracing_sweep(quick: bool) -> Result<Vec<Json>> {
    let (m, k, iters) = if quick { (512, 8, 60) } else { (2048, 16, 160) };
    let clients = 4usize;

    let mut table = Table::new(&["tracing", "clients", "req/s", "p50", "p95", "spans/req"]);
    let mut rows: Vec<Json> = Vec::new();
    for (config, trace) in [("off", false), ("on", true)] {
        let svc = Arc::new(SamplingService::new(ServiceConfig {
            shards: 4,
            ..Default::default()
        }));
        let mut rng = Xoshiro::seeded(7);
        svc.register("traced", tablelike_kernel(m, k, &mut rng));
        let wall = Timer::start();
        let mut latencies: Vec<f64> = Vec::with_capacity(clients * iters);
        let mut spans_seen = 0usize;
        let mut span_bytes = 0usize;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let svc = Arc::clone(&svc);
                    scope.spawn(move || {
                        let mut lats = Vec::with_capacity(iters);
                        let (mut spans, mut bytes) = (0usize, 0usize);
                        for i in 0..iters {
                            let t = Timer::start();
                            let resp = svc
                                .sample(SampleRequest {
                                    model: "traced".into(),
                                    n: SAMPLES_PER_REQUEST,
                                    seed: Some(((c as u64) << 32) | i as u64),
                                    kind: SamplerKind::Cholesky,
                                    trace,
                                    ..Default::default()
                                })
                                .expect("tracing bench request failed");
                            if trace {
                                // the serialization cost a traced wire
                                // response pays on top of the samples
                                bytes += Trace::spans_json(&resp.trace).to_string().len();
                            }
                            lats.push(t.secs());
                            spans += resp.trace.len();
                        }
                        (lats, spans, bytes)
                    })
                })
                .collect();
            for h in handles {
                let (lats, spans, bytes) = h.join().expect("tracing bench client panicked");
                latencies.extend(lats);
                spans_seen += spans;
                span_bytes += bytes;
            }
        });
        let wall = wall.secs();
        let requests = (clients * iters) as f64;
        let req_s = requests / wall;
        let lat = Summary::of(&latencies);
        let spans_per_req = spans_seen as f64 / requests;
        table.row(vec![
            config.to_string(),
            format!("{clients}"),
            format!("{req_s:.0}"),
            fmt_secs(lat.p50),
            fmt_secs(lat.p95),
            format!("{spans_per_req:.1}"),
        ]);
        rows.push(
            Json::obj()
                .with("config", config)
                .with("clients", clients)
                .with("requests", requests)
                .with("wall_s", wall)
                .with("requests_per_s", req_s)
                .with("latency_p50_s", lat.p50)
                .with("latency_p95_s", lat.p95)
                .with("latency_mean_s", lat.mean)
                .with("spans_per_request", spans_per_req)
                .with("span_payload_bytes", span_bytes),
        );
    }
    println!("\n== tracing overhead (M={m}, 2K={}) ==\n{}", 2 * k, table.render());
    Ok(rows)
}

/// `clients` threads each issue `iters` synchronous requests back to back
/// (each carrying the `given` basket — empty for unconditional traffic);
/// returns (wall seconds, every per-request latency).
fn closed_loop(
    svc: &Arc<SamplingService>,
    kind: SamplerKind,
    clients: usize,
    iters: usize,
    given: &[usize],
) -> (f64, Vec<f64>) {
    let wall = Timer::start();
    let mut latencies: Vec<f64> = Vec::with_capacity(clients * iters);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let svc = Arc::clone(svc);
                let given = given.to_vec();
                scope.spawn(move || {
                    let mut lats = Vec::with_capacity(iters);
                    for i in 0..iters {
                        let t = Timer::start();
                        svc.sample(SampleRequest {
                            model: "bench".into(),
                            n: SAMPLES_PER_REQUEST,
                            seed: Some(((c as u64) << 32) | i as u64),
                            kind,
                            deadline: None,
                            given: given.clone(),
                            chain: false,
                            trace: false,
                        })
                        .expect("bench request failed");
                        lats.push(t.secs());
                    }
                    lats
                })
            })
            .collect();
        for h in handles {
            latencies.extend(h.join().expect("bench client panicked"));
        }
    });
    (wall.secs(), latencies)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_measures_and_reproduces() {
        let svc = Arc::new(SamplingService::new(ServiceConfig {
            shards: 2,
            ..Default::default()
        }));
        let mut rng = Xoshiro::seeded(3);
        svc.register("bench", tablelike_kernel(64, 4, &mut rng));
        let (wall, lats) = closed_loop(&svc, SamplerKind::Cholesky, 2, 3, &[]);
        assert!(wall > 0.0);
        assert_eq!(lats.len(), 6);
        assert!(lats.iter().all(|&l| l >= 0.0));
        // conditional traffic flows through the same loop
        let (wall_c, lats_c) = closed_loop(&svc, SamplerKind::Rejection, 1, 2, &[1, 5]);
        assert!(wall_c > 0.0);
        assert_eq!(lats_c.len(), 2);
    }
}
