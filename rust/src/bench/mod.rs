//! Benchmark harness (criterion substitute) + the per-experiment drivers
//! that regenerate every table and figure of the paper's evaluation
//! (DESIGN.md §5 experiment index).

pub mod experiments;
pub mod linalg_backends;
pub mod runner;
pub mod serving;

pub use runner::{BenchRunner, Measurement};
