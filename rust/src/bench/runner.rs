//! Measurement harness: warmup, repeated timing, summary statistics.

use crate::util::stats::Summary;
use crate::util::Timer;

/// One named measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub summary: Summary,
}

impl Measurement {
    pub fn mean(&self) -> f64 {
        self.summary.mean
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchRunner {
    pub warmup: usize,
    pub iters: usize,
    /// stop early once this much wall clock has been spent measuring
    pub max_secs: f64,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup: 1, iters: 10, max_secs: 10.0 }
    }
}

impl BenchRunner {
    pub fn quick() -> BenchRunner {
        BenchRunner { warmup: 1, iters: 5, max_secs: 3.0 }
    }

    /// Time `f` (seconds per call) with warmup and an adaptive iteration
    /// budget.
    pub fn measure(&self, name: &str, mut f: impl FnMut()) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.iters);
        let budget = Timer::start();
        for _ in 0..self.iters {
            let t = Timer::start();
            f();
            times.push(t.secs());
            if budget.secs() > self.max_secs && times.len() >= 3 {
                break;
            }
        }
        Measurement { name: name.to_string(), summary: Summary::of(&times) }
    }
}

/// Fixed-width table printer for experiment reports.
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = width[i]))
                .collect();
            format!("| {} |\n", parts.join(" | "))
        };
        out.push_str(&fmt_row(&self.header, &width));
        let sep: Vec<String> = width.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_stats() {
        let r = BenchRunner { warmup: 1, iters: 5, max_secs: 1.0 };
        let m = r.measure("sleep", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(m.summary.n >= 3);
        assert!(m.mean() >= 0.0015, "mean={}", m.mean());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("| name      | value |"), "{s}");
        assert_eq!(s.lines().count(), 4);
    }
}
