//! Experiment drivers regenerating every table and figure of the paper's
//! evaluation (§6).  Each driver prints the paper-shaped table and returns
//! a JSON record that `ndpp reproduce` writes under `results/`.
//!
//! Scaling notes (DESIGN.md §4): absolute wall-clocks are this machine's,
//! not the authors'; the claims being reproduced are *shapes* — who wins,
//! by roughly what factor, and how costs grow with M.

use anyhow::Result;

use crate::bench::runner::{BenchRunner, Table};
use crate::coordinator::registry::ModelEntry;
use crate::data::{recipes, synthetic};
use crate::learn::{self, TrainConfig, Trainer};
use crate::linalg::Matrix;
use crate::ndpp::{NdppKernel, Proposal};
use crate::rng::Xoshiro;
use crate::runtime::ModelOps;
use crate::sampler::{
    CholeskySampler, DenseCholeskySampler, McmcConfig, McmcSampler, RejectionSampler,
    SampleTree, Sampler, TreeConfig,
};
use crate::util::json::Json;
use crate::util::timer::{fmt_secs, timed};

/// Shared experiment options.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// "fast" (CI-friendly) or "paper" (full catalog sizes where feasible)
    pub profile: String,
    pub seed: u64,
    /// per-part rank K for sampling experiments
    pub k: usize,
    pub runner: BenchRunner,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            profile: "fast".into(),
            seed: 0,
            k: 32,
            runner: BenchRunner::default(),
        }
    }
}

fn emit(name: &str, table: &Table, json: &Json) -> Result<String> {
    let rendered = table.render();
    println!("\n== {name} ==\n{rendered}");
    std::fs::create_dir_all("results").ok();
    let path = format!("results/{name}.json");
    std::fs::write(&path, json.to_string_pretty())?;
    println!("(written to {path})");
    Ok(rendered)
}

/// A Table-3-like kernel for a dataset stand-in: random ONDPP features at
/// the dataset's catalog size with sigmas in the range regularized training
/// produces (the paper's Table 2 "with regularization" rows keep expected
/// rejections in the tens; sampling speed depends on the kernel only
/// through M, K and those sigmas).
pub fn tablelike_kernel(m: usize, k: usize, rng: &mut Xoshiro) -> NdppKernel {
    let mut kernel = NdppKernel::random_ondpp(m, k, rng);
    // sigma scale chosen so E[#rejections] lands in the paper's Table 2
    // "with regularization" range (~20-80) at K=100-ish ranks
    for s in &mut kernel.sigma {
        *s = rng.uniform_in(0.05, 0.15);
    }
    // basket-sized samples (the paper's k << K regime)
    kernel.rescale_expected_size(10.0);
    kernel
}

/// A kernel with **no** ONDPP structure: `B` column-normalized but not
/// orthonormalized, `V` not orthogonal to `B`, every Youla value set to
/// `sigma` — the class of kernels unconstrained NDPP training produces.
/// At `sigma ~ 1` the rejection sampler's expected proposal count grows
/// like `2^{K/2}` (Theorem 2's bound no longer applies, and the measured
/// `det(L̂+I)/det(L+I)` tracks the same explosion), which is the regime the
/// MCMC up-down sampler exists for.
pub fn nonorthogonal_kernel(m: usize, k: usize, sigma: f64, rng: &mut Xoshiro) -> NdppKernel {
    assert!(k >= 2 && k % 2 == 0);
    let scale = (k as f64 / m as f64).sqrt().min(0.5);
    let v = Matrix::randn(m, k, scale, rng);
    let mut b = Matrix::randn(m, k, 1.0, rng);
    for j in 0..k {
        let norm = (0..m).map(|i| b[(i, j)] * b[(i, j)]).sum::<f64>().sqrt();
        if norm > 0.0 {
            for i in 0..m {
                b[(i, j)] /= norm;
            }
        }
    }
    NdppKernel::new(v, b, vec![sigma; k / 2])
}

// ======================================================================
// MCMC vs rejection — sampling cost as the ONDPP regularization is
// relaxed (the follow-up paper's motivating comparison)
// ======================================================================

/// Above this expected proposal count the rejection sampler is not timed
/// (a single sample would need thousands of proposals); the MCMC column
/// keeps going, which is the point of the experiment.
const REJECTION_FEASIBILITY_CUTOFF: f64 = 200.0;

pub fn mcmc_comparison(opts: &ExpOptions) -> Result<String> {
    let m = if opts.profile == "paper" { 4096usize } else { 512usize };
    // per-part rank from the shared sampling-experiment knob, rounded down
    // to the even value the kernel parameterization requires; U ~ 2^{K/2}
    // at sigma ~ 1, so K >= ~16 is needed to reach the infeasible regime
    let k = ((opts.k.max(2)) / 2) * 2;
    // sigma sweep: small values mimic gamma-regularized ONDPP training,
    // sigma ~ 1 the unregularized/nonorthogonal end where U ~ 2^{K/2}
    let sigmas = [0.05, 0.15, 0.4, 0.7, 1.0];

    let mut table = Table::new(&[
        "sigma",
        "E[#rejections]",
        "rejection / sample",
        "mcmc / sample",
        "mcmc steps/sample",
        "mcmc acceptance",
    ]);
    let mut json_rows = Vec::new();

    for (idx, &sigma) in sigmas.iter().enumerate() {
        let mut rng = Xoshiro::seeded(opts.seed ^ (0xA11 + idx as u64));
        let kernel = nonorthogonal_kernel(m, k, sigma, &mut rng);
        let proposal = Proposal::build(&kernel);
        let u = proposal.expected_rejections();

        let rejection_mean = if u <= REJECTION_FEASIBILITY_CUTOFF {
            let tree = SampleTree::build(&proposal.spectral(), TreeConfig::default());
            let mut rej = RejectionSampler::new(&kernel, &proposal, &tree);
            let mut r = Xoshiro::seeded(13);
            Some(opts.runner.measure("rej", || {
                rej.sample(&mut r);
            }))
        } else {
            None
        };

        let config = McmcConfig::for_kernel(&kernel);
        let mut mcmc = McmcSampler::new(&kernel, config);
        let mut r = Xoshiro::seeded(14);
        let mc = opts.runner.measure("mcmc", || {
            mcmc.sample(&mut r);
        });

        table.row(vec![
            format!("{sigma}"),
            format!("{u:.3e}"),
            rejection_mean
                .as_ref()
                .map(|mr| fmt_secs(mr.mean()))
                .unwrap_or_else(|| "infeasible".into()),
            fmt_secs(mc.mean()),
            format!("{}", mcmc.last_steps),
            format!("{:.2}", mcmc.acceptance_rate()),
        ]);
        json_rows.push(
            Json::obj()
                .with("sigma", sigma)
                .with("m", m)
                .with("k", k)
                .with("expected_rejections", u)
                .with(
                    "rejection_s",
                    rejection_mean.map(|mr| Json::Num(mr.mean())).unwrap_or(Json::Null),
                )
                .with("mcmc_s", mc.mean())
                .with("mcmc_size", config.size)
                .with("mcmc_steps_per_sample", mcmc.last_steps)
                .with("mcmc_acceptance", mcmc.acceptance_rate()),
        );
    }
    let json = Json::obj()
        .with("m", m)
        .with("k", k)
        .with("cutoff", REJECTION_FEASIBILITY_CUTOFF)
        .with("rows", Json::Arr(json_rows));
    emit("mcmc_comparison", &table, &json)
}

// ======================================================================
// Table 1 — complexity summary, confirmed by measured scaling exponents
// ======================================================================

pub fn table1(opts: &ExpOptions) -> Result<String> {
    let k = opts.k.min(16);
    let ms = if opts.profile == "paper" {
        vec![1 << 12, 1 << 14, 1 << 16]
    } else {
        vec![1 << 10, 1 << 12, 1 << 14]
    };
    let mut chol_times = Vec::new();
    let mut rej_times = Vec::new();
    for &m in &ms {
        let mut rng = Xoshiro::seeded(opts.seed ^ m as u64);
        let kernel = tablelike_kernel(m, k, &mut rng);
        let entry = ModelEntry::prepare("t1", kernel, TreeConfig::default());
        let mut chol = CholeskySampler::from_marginal(&entry.marginal);
        let mut rej = RejectionSampler::new(&entry.kernel, &entry.proposal, &entry.tree);
        let mut r1 = Xoshiro::seeded(1);
        let mc = opts.runner.measure("chol", || {
            chol.sample(&mut r1);
        });
        let mr = opts.runner.measure("rej", || {
            rej.sample(&mut r1);
        });
        chol_times.push(mc.mean());
        rej_times.push(mr.mean());
    }
    // fit log-log slope between first and last point
    let slope = |ts: &[f64]| {
        let dm = (ms[ms.len() - 1] as f64 / ms[0] as f64).ln();
        (ts[ts.len() - 1] / ts[0]).ln() / dm
    };
    let s_chol = slope(&chol_times);
    let s_rej = slope(&rej_times);

    let mut t = Table::new(&[
        "algorithm",
        "paper sampling time",
        "measured M-exponent",
        "verdict",
    ]);
    t.row(vec![
        "linear-time Cholesky".into(),
        "O(M K^2)".into(),
        format!("{s_chol:.2}"),
        if (0.6..1.4).contains(&s_chol) { "linear ✓" } else { "⚠" }.into(),
    ]);
    t.row(vec![
        "sublinear rejection".into(),
        "O((k^3 log M + k^4 + K)(1+w)^K)".into(),
        format!("{s_rej:.2}"),
        if s_rej < 0.5 { "sublinear ✓" } else { "⚠" }.into(),
    ]);
    let json = Json::obj()
        .with("ms", ms.iter().map(|&m| Json::Num(m as f64)).collect::<Vec<_>>())
        .with("cholesky_secs", chol_times.clone())
        .with("rejection_secs", rej_times.clone())
        .with("cholesky_exponent", s_chol)
        .with("rejection_exponent", s_rej);
    emit("table1", &t, &json)
}

// ======================================================================
// Table 2 — predictive performance of the four model classes
// ======================================================================

/// Table-2 learning scale: datasets are regenerated at the largest catalog
/// size covered by the exported train_step artifacts.
pub fn table2(opts: &ExpOptions, ops: &ModelOps) -> Result<String> {
    // artifact config: m=2048, k=32, b=64, s=16 (see aot.py CONFIGS)
    let (m, k, bsz, kmax) = (2048usize, 32usize, 64usize, 16usize);
    let steps = if opts.profile == "paper" { 400 } else { 120 };

    let mut table = Table::new(&[
        "dataset", "model", "MPR", "AUC", "log-lik", "E[#rejections]",
    ]);
    let mut json_rows: Vec<Json> = Vec::new();

    for recipe in recipes::standard_datasets("fast") {
        // regenerate the recipe at the trainable catalog size; seed and
        // cluster structure vary per dataset so the five stand-ins remain
        // distinct after rescaling
        let mut name_hash = opts.seed ^ 0xD5;
        for b in recipe.name.bytes() {
            name_hash = name_hash.wrapping_mul(31).wrapping_add(b as u64);
        }
        let mut cfg = recipe.config.clone();
        cfg.m = m;
        cfg.n_baskets = cfg.n_baskets.min(3000);
        cfg.clusters = cfg.clusters.min(m / 8);
        let mut rng = Xoshiro::seeded(name_hash);
        let mut ds = synthetic::generate_baskets(&cfg, &mut rng);
        ds.trim(kmax);
        let split = ds.split(100, 400, &mut rng);
        let mu = ds.item_frequencies();

        // the four model classes of Table 2
        let models: Vec<(&str, TrainConfig)> = vec![
            (
                "symmetric-dpp",
                TrainConfig {
                    k, batch_size: bsz, kmax, steps, gamma: 50.0, project: false,
                    seed: opts.seed, ..Default::default()
                },
            ),
            (
                "ndpp",
                TrainConfig {
                    k, batch_size: bsz, kmax, steps, gamma: 0.0, project: false,
                    seed: opts.seed, ..Default::default()
                },
            ),
            (
                "ondpp",
                TrainConfig {
                    k, batch_size: bsz, kmax, steps, gamma: 0.0, project: true,
                    seed: opts.seed, ..Default::default()
                },
            ),
            (
                "ondpp+reg",
                TrainConfig {
                    k, batch_size: bsz, kmax, steps, gamma: 0.5, project: true,
                    seed: opts.seed, ..Default::default()
                },
            ),
        ];

        for (name, tc) in models {
            let trainer = Trainer::new(ops, m, split.train.clone(), mu.clone(), tc)?;
            let model = trainer.run(|_, _| {})?;
            let kernel = &model.kernel;
            let mk = crate::ndpp::MarginalKernel::build(kernel);
            let mut eval_rng = Xoshiro::seeded(opts.seed ^ 0xE7A1);
            let mpr = learn::mpr(kernel, &split.test, &mut eval_rng);
            let auc = learn::auc(kernel, mk.logdet_l_plus_i, &split.test, &mut eval_rng);
            let ll = learn::test_loglik(kernel, mk.logdet_l_plus_i, &split.test);
            let rejections = Proposal::build(kernel).expected_rejections();
            table.row(vec![
                recipe.name.into(),
                name.into(),
                format!("{mpr:.2}"),
                format!("{auc:.3}"),
                format!("{ll:.2}"),
                format!("{rejections:.3e}"),
            ]);
            json_rows.push(
                Json::obj()
                    .with("dataset", recipe.name)
                    .with("model", name)
                    .with("mpr", mpr)
                    .with("auc", auc)
                    .with("loglik", ll)
                    .with("rejections", rejections),
            );
        }
    }
    let json = Json::obj()
        .with("m", m)
        .with("k", k)
        .with("steps", steps)
        .with("rows", Json::Arr(json_rows));
    emit("table2", &table, &json)
}

// ======================================================================
// Table 3 — preprocessing + sampling wall-clock on the dataset stand-ins
// ======================================================================

pub fn table3(opts: &ExpOptions) -> Result<String> {
    let k = opts.k;
    let mut table = Table::new(&[
        "dataset",
        "M",
        "spectral prep",
        "tree prep",
        "cholesky / sample",
        "rejection / sample",
        "speedup",
        "tree memory",
    ]);
    let mut json_rows: Vec<Json> = Vec::new();

    for recipe in recipes::standard_datasets(&opts.profile) {
        let m = recipe.config.m;
        let mut rng = Xoshiro::seeded(opts.seed ^ recipe.paper_m as u64);
        let kernel = tablelike_kernel(m, k, &mut rng);

        let (marginal, t_marginal) =
            timed(|| crate::ndpp::MarginalKernel::build(&kernel));
        let (proposal, t_spectral) = timed(|| Proposal::build(&kernel));
        let (spectral, t_spec2) = timed(|| proposal.spectral());
        let (tree, t_tree) = timed(|| SampleTree::build(&spectral, TreeConfig::default()));
        let t_spectral = t_spectral + t_spec2;

        let mut chol = CholeskySampler::from_marginal(&marginal);
        let mut rej = RejectionSampler::new(&kernel, &proposal, &tree);
        let mut r = Xoshiro::seeded(7);
        let mc = opts.runner.measure("chol", || {
            chol.sample(&mut r);
        });
        let mr = opts.runner.measure("rej", || {
            rej.sample(&mut r);
        });
        let speedup = mc.mean() / mr.mean();
        let mem = tree.memory_bytes();

        table.row(vec![
            recipe.name.into(),
            format!("{m}"),
            fmt_secs(t_spectral),
            fmt_secs(t_tree),
            format!("{} ±{}", fmt_secs(mc.mean()), fmt_secs(mc.summary.ci95)),
            format!("{} ±{}", fmt_secs(mr.mean()), fmt_secs(mr.summary.ci95)),
            format!("×{speedup:.1}"),
            format!("{:.1} MB", mem as f64 / 1e6),
        ]);
        json_rows.push(
            Json::obj()
                .with("dataset", recipe.name)
                .with("m", m)
                .with("k", k)
                .with("marginal_prep_s", t_marginal)
                .with("spectral_prep_s", t_spectral)
                .with("tree_prep_s", t_tree)
                .with("cholesky_s", mc.mean())
                .with("rejection_s", mr.mean())
                .with("speedup", speedup)
                .with("tree_bytes", mem)
                .with("observed_rejections", rej.observed_rejection_rate())
                .with("expected_rejections", rej.expected_rejection_rate()),
        );
    }
    let json = Json::obj().with("k", k).with("rows", Json::Arr(json_rows));
    emit("table3", &table, &json)
}

// ======================================================================
// Fig 1 — gamma sweep: rejection count vs predictive quality
// ======================================================================

pub fn fig1(opts: &ExpOptions, ops: &ModelOps) -> Result<String> {
    let (m, k, bsz, kmax) = (2048usize, 32usize, 64usize, 16usize);
    let steps = if opts.profile == "paper" { 300 } else { 100 };
    // NOTE: Adam normalizes per-parameter gradient scale, so once the
    // gamma term dominates the sigma gradient the trajectory is
    // gamma-invariant; the informative sweep is therefore over small
    // gammas where the likelihood and regularizer gradients compete
    // (the paper's Fig 1 x-axis is likewise log-scale in this regime).
    let gammas = [0.0, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 1e-1];

    // uk_retail-like data at trainable scale
    let recipe = recipes::dataset_by_name("uk_retail_synth", "fast").unwrap();
    let mut cfg = recipe.config.clone();
    cfg.m = m;
    cfg.n_baskets = 2500;
    cfg.clusters = 120;
    let mut rng = Xoshiro::seeded(opts.seed ^ 0xF16);
    let mut ds = synthetic::generate_baskets(&cfg, &mut rng);
    ds.trim(kmax);
    let split = ds.split(100, 400, &mut rng);
    let mu = ds.item_frequencies();

    let mut table = Table::new(&["gamma", "E[#rejections]", "test log-lik"]);
    let mut json_rows = Vec::new();
    for &gamma in &gammas {
        let tc = TrainConfig {
            k, batch_size: bsz, kmax, steps, gamma, project: true,
            seed: opts.seed, ..Default::default()
        };
        let trainer = Trainer::new(ops, m, split.train.clone(), mu.clone(), tc)?;
        let model = trainer.run(|_, _| {})?;
        let mk = crate::ndpp::MarginalKernel::build(&model.kernel);
        let ll = learn::test_loglik(&model.kernel, mk.logdet_l_plus_i, &split.test);
        let rejections = Proposal::build(&model.kernel).expected_rejections();
        table.row(vec![
            format!("{gamma}"),
            format!("{rejections:.3}"),
            format!("{ll:.3}"),
        ]);
        json_rows.push(
            Json::obj()
                .with("gamma", gamma)
                .with("rejections", rejections)
                .with("loglik", ll),
        );
    }
    let json = Json::obj().with("steps", steps).with("rows", Json::Arr(json_rows));
    emit("fig1", &table, &json)
}

// ======================================================================
// Fig 2 — synthetic scaling: sampling (a) and preprocessing (b) vs M
// ======================================================================

pub fn fig2(opts: &ExpOptions) -> Result<String> {
    let k = opts.k;
    let exps: Vec<u32> = if opts.profile == "paper" {
        (12..=20).collect()
    } else {
        (10..=16).step_by(2).collect()
    };

    let mut table = Table::new(&[
        "M",
        "cholesky / sample",
        "rejection / sample",
        "dense O(M^3) / sample",
        "spectral prep",
        "tree prep",
    ]);
    let mut json_rows = Vec::new();

    for &e in &exps {
        let m = 1usize << e;
        let mut rng = Xoshiro::seeded(opts.seed ^ m as u64);
        // the paper's §6.2 synthetic feature scheme
        let mut kernel = NdppKernel::synthetic(m, k, &mut rng);
        // regularized-scale sigmas so the rejection rate stays bounded
        for s in &mut kernel.sigma {
            *s = rng.uniform_in(0.02, 0.25);
        }
        kernel.orthogonalize();
        kernel.rescale_expected_size(10.0);

        let (marginal, _) = timed(|| crate::ndpp::MarginalKernel::build(&kernel));
        let (proposal, t_prop) = timed(|| Proposal::build(&kernel));
        let (spectral, t_spec) = timed(|| proposal.spectral());
        let (tree, t_tree) = timed(|| SampleTree::build(&spectral, TreeConfig::default()));

        let mut chol = CholeskySampler::from_marginal(&marginal);
        let mut rej = RejectionSampler::new(&kernel, &proposal, &tree);
        let mut r = Xoshiro::seeded(11);
        let mc = opts.runner.measure("chol", || {
            chol.sample(&mut r);
        });
        let mr = opts.runner.measure("rej", || {
            rej.sample(&mut r);
        });
        // dense baseline only at small M (O(M^3) explodes)
        let dense_mean = if m <= 4096 {
            let mut dense = DenseCholeskySampler::new(&kernel);
            let md = BenchRunner::quick().measure("dense", || {
                dense.sample(&mut r);
            });
            Some(md.mean())
        } else {
            None
        };

        table.row(vec![
            format!("2^{e}"),
            fmt_secs(mc.mean()),
            fmt_secs(mr.mean()),
            dense_mean.map(fmt_secs).unwrap_or_else(|| "—".into()),
            fmt_secs(t_prop + t_spec),
            fmt_secs(t_tree),
        ]);
        json_rows.push(
            Json::obj()
                .with("m", m)
                .with("cholesky_s", mc.mean())
                .with("rejection_s", mr.mean())
                .with("dense_s", dense_mean.map(Json::Num).unwrap_or(Json::Null))
                .with("spectral_prep_s", t_prop + t_spec)
                .with("tree_prep_s", t_tree)
                .with("observed_rejections", rej.observed_rejection_rate()),
        );
    }
    let json = Json::obj().with("k", k).with("rows", Json::Arr(json_rows));
    emit("fig2", &table, &json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tablelike_kernel_has_small_rejection_rate() {
        let mut rng = Xoshiro::seeded(1);
        let kernel = tablelike_kernel(256, 16, &mut rng);
        let p = Proposal::build(&kernel);
        assert!(p.expected_rejections() < 50.0, "{}", p.expected_rejections());
        assert!(kernel.is_ondpp(1e-8));
    }

    #[test]
    fn nonorthogonal_kernel_rejections_diverge_and_mcmc_survives() {
        // the acceptance criterion of the MCMC subsystem: a kernel whose
        // expected rejection count exceeds 10^3 — useless for the rejection
        // sampler — still samples fine through the up-down chain
        let mut rng = Xoshiro::seeded(2);
        let kernel = nonorthogonal_kernel(128, 24, 1.0, &mut rng);
        let p = Proposal::build(&kernel);
        assert!(
            p.expected_rejections() > 1e3,
            "expected rejections only {:.3e}",
            p.expected_rejections()
        );
        let config = McmcConfig::for_kernel(&kernel);
        let mut s = McmcSampler::new(&kernel, config);
        let y = s.sample(&mut rng);
        assert_eq!(y.len(), config.size);
        assert!(s.acceptance_rate() > 0.0);
    }

    #[test]
    fn mcmc_comparison_runs_and_flags_infeasible_rejection() {
        let opts = ExpOptions {
            k: 24,
            runner: BenchRunner::quick(),
            ..Default::default()
        };
        let rendered = mcmc_comparison(&opts).unwrap();
        // the sigma ~ 1 rows must be beyond the rejection sampler
        assert!(rendered.contains("infeasible"), "{rendered}");
    }

    #[test]
    fn table1_runs_in_fast_profile() {
        let opts = ExpOptions {
            k: 8,
            runner: BenchRunner::quick(),
            ..Default::default()
        };
        // smoke: runs end-to-end and emits a table
        let rendered = table1(&opts).unwrap();
        assert!(rendered.contains("linear-time Cholesky"));
    }
}
