//! Seedable pseudo-random number generation.
//!
//! crates.io is unavailable in this environment, so instead of `rand` we
//! ship a small, well-tested implementation of **xoshiro256++** (Blackman &
//! Vigna) implementing the vendored [`rand_core`] traits, plus the handful
//! of distributions the samplers and dataset generators need (uniform,
//! standard normal via Marsaglia polar, Poisson, categorical/alias-free
//! weighted choice, Fisher–Yates shuffle).
//!
//! Determinism matters: every sampler takes an explicit `&mut Xoshiro`, and
//! the coordinator derives independent per-request streams with
//! [`Xoshiro::split`] (a SplitMix64 jump of the seed) so batched sampling is
//! reproducible regardless of worker interleaving.

use rand_core::{Error as RandError, RngCore, SeedableRng};

/// xoshiro256++ PRNG — 256-bit state, period `2^256 - 1`, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro {
    s: [u64; 4],
}

/// Domain-separation constant for coordinator request streams (arbitrary
/// odd 64-bit value; see [`request_stream`]).
const REQUEST_STREAM_DOMAIN: u64 = 0x9D5C_41F7_2E8B_A6D3;

/// The sample stream for a service request carrying `seed`.
///
/// This is **the** seed-stream derivation of the serving pipeline: a pure
/// function of the request seed alone, so the samples a request produces
/// are independent of shard assignment, batch composition, worker
/// interleaving, queue pressure, and service instance — the coordinator's
/// reproducibility contract (`(model, seed, n)` → byte-identical samples).
///
/// The stream is domain-separated from plain [`Xoshiro::seeded`] so a
/// service request seeded `s` never shares a stream with library code that
/// seeded an rng with the same integer (e.g. the kernel generator that
/// built the model being sampled).
pub fn request_stream(seed: u64) -> Xoshiro {
    let mut sm = seed ^ REQUEST_STREAM_DOMAIN;
    Xoshiro::seeded(splitmix64(&mut sm))
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 — used to expand seeds into full state (per Vigna's
/// recommendation) and to derive independent streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Xoshiro {
    /// Construct from a 64-bit seed (expanded through SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // the all-zero state is invalid; splitmix cannot produce 4 zeros
        // from any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E3779B97F4A7C15;
        }
        Self { s }
    }

    /// Derive an independent stream keyed by `stream` (stable across runs).
    pub fn split(&self, stream: u64) -> Self {
        let mut sm = self.s[0] ^ self.s[2] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64_impl(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64_impl() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64_impl();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64_impl();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via the Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Poisson-distributed integer (Knuth for small mean, PTRS-style
    /// normal approximation fallback for large mean).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // normal approximation with continuity correction; fine for the
            // dataset generators where mean is O(10..100).
            let x = self.normal_with(mean, mean.sqrt()) + 0.5;
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Sample an index proportionally to `weights` (all nonnegative).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with zero total mass");
        let mut t = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates on an
    /// index map; O(k) memory for k << n via a hash-free swap table).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small n just shuffle a full index vector.
        if n <= 4 * k || n <= 64 {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            return idx;
        }
        // Floyd's algorithm for k distinct values out of n.
        let mut chosen: Vec<usize> = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }

    /// Vector of `n` uniforms in `[0,1)`.
    pub fn uniform_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.uniform()).collect()
    }
}

impl RngCore for Xoshiro {
    fn next_u32(&mut self) -> u32 {
        (self.next_u64_impl() >> 32) as u32
    }
    fn next_u64(&mut self) -> u64 {
        self.next_u64_impl()
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        let mut chunks = dest.chunks_exact_mut(8);
        for chunk in &mut chunks {
            chunk.copy_from_slice(&self.next_u64_impl().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let bytes = self.next_u64_impl().to_le_bytes();
            rem.copy_from_slice(&bytes[..rem.len()]);
        }
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), RandError> {
        self.fill_bytes(dest);
        Ok(())
    }
}

impl SeedableRng for Xoshiro {
    type Seed = [u8; 32];
    fn from_seed(seed: Self::Seed) -> Self {
        let mut s = [0u64; 4];
        for (i, slot) in s.iter_mut().enumerate() {
            let mut b = [0u8; 8];
            b.copy_from_slice(&seed[i * 8..(i + 1) * 8]);
            *slot = u64::from_le_bytes(b);
        }
        if s == [0, 0, 0, 0] {
            return Self::seeded(0);
        }
        Self { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Xoshiro::seeded(42);
        let mut b = Xoshiro::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64_impl(), b.next_u64_impl());
        }
    }

    #[test]
    fn request_stream_is_deterministic_and_domain_separated() {
        let mut a = request_stream(42);
        let mut b = request_stream(42);
        let mut plain = Xoshiro::seeded(42);
        let mut collisions = 0;
        for _ in 0..64 {
            let x = a.next_u64_impl();
            assert_eq!(x, b.next_u64_impl());
            if x == plain.next_u64_impl() {
                collisions += 1;
            }
        }
        assert_eq!(collisions, 0, "request stream must not alias the plain stream");
        // distinct seeds -> distinct streams
        let mut c = request_stream(42);
        let mut d = request_stream(43);
        let same = (0..64)
            .filter(|_| c.next_u64_impl() == d.next_u64_impl())
            .count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_differ() {
        let base = Xoshiro::seeded(1);
        let mut s1 = base.split(0);
        let mut s2 = base.split(1);
        let same = (0..64).filter(|_| s1.next_u64_impl() == s2.next_u64_impl()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_mean() {
        let mut rng = Xoshiro::seeded(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiasedish() {
        let mut rng = Xoshiro::seeded(9);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 7.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro::seeded(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut rng = Xoshiro::seeded(5);
        for &lambda in &[0.5, 5.0, 80.0] {
            let n = 20_000;
            let mean =
                (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < 0.05 * lambda + 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn choose_distinct_is_distinct_and_in_range() {
        let mut rng = Xoshiro::seeded(6);
        for &(n, k) in &[(10usize, 10usize), (1000, 5), (50, 25), (1, 1)] {
            let mut got = rng.choose_distinct(n, k);
            got.sort_unstable();
            got.dedup();
            assert_eq!(got.len(), k, "n={n} k={k}");
            assert!(got.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_matches_weights() {
        let mut rng = Xoshiro::seeded(8);
        let w = [1.0, 3.0, 6.0];
        let n = 30_000;
        let mut counts = [0usize; 3];
        for _ in 0..n {
            counts[rng.weighted(&w)] += 1;
        }
        for i in 0..3 {
            let expect = n as f64 * w[i] / 10.0;
            assert!((counts[i] as f64 - expect).abs() < 6.0 * expect.sqrt());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro::seeded(10);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fill_bytes_covers_remainder() {
        let mut rng = Xoshiro::seeded(11);
        let mut buf = [0u8; 13];
        rng.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
