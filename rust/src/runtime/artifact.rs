//! Artifact manifest: shape-keyed index of the AOT-exported HLO files.
//!
//! `python -m compile.aot` writes `artifacts/manifest.json` describing every
//! exported graph (name, shape config, input/output specs).  The runtime
//! loads the manifest once and resolves `(name, shape)` lookups against it.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One tensor spec (shape + dtype name as jax reports it).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One exported artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub config: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|t| {
            let shape = t
                .req("shape")?
                .as_arr()
                .ok_or_else(|| anyhow!("bad shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSpec { shape, dtype: t.str_or("dtype", "float32") })
        })
        .collect()
}

impl ArtifactManifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = Vec::new();
        for a in json
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow!("manifest.artifacts must be an array"))?
        {
            artifacts.push(ArtifactSpec {
                name: a.str_or("name", ""),
                config: a.str_or("config", ""),
                file: dir.join(a.str_or("file", "")),
                inputs: tensor_specs(a.req("inputs")?)?,
                outputs: tensor_specs(a.req("outputs")?)?,
            });
        }
        Ok(ArtifactManifest { dir, artifacts })
    }

    /// Try to load from the conventional location (`./artifacts`), else an
    /// explicit `NDPP_ARTIFACTS` env override.  Returns None when absent —
    /// callers fall back to native implementations.
    pub fn discover() -> Option<ArtifactManifest> {
        let dir = std::env::var("NDPP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        ArtifactManifest::load(dir).ok()
    }

    /// Find an artifact by name + exact first-input leading dimension
    /// (the item count M) — the lookup used by samplers.
    pub fn find(&self, name: &str, m: usize, k2: usize) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| {
            a.name == name
                && a.inputs
                    .first()
                    .is_some_and(|t| t.shape.first() == Some(&m) && t.shape.get(1) == Some(&k2))
        })
    }

    /// Find by name + config string.
    pub fn find_config(&self, name: &str, config: &str) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name && a.config == config)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        let manifest = Json::obj().with("format", 1u64).with(
            "artifacts",
            Json::arr([Json::obj()
                .with("name", "marginal_diag")
                .with("config", "m8_k2")
                .with("file", "marginal_diag_m8_k2.hlo.txt")
                .with(
                    "inputs",
                    Json::arr([
                        Json::obj()
                            .with("shape", vec![8usize, 4])
                            .with("dtype", "float32"),
                        Json::obj()
                            .with("shape", vec![4usize, 4])
                            .with("dtype", "float32"),
                    ]),
                )
                .with(
                    "outputs",
                    Json::arr([Json::obj()
                        .with("shape", vec![8usize])
                        .with("dtype", "float32")]),
                )]),
        );
        std::fs::write(dir.join("manifest.json"), manifest.to_string()).unwrap();
    }

    #[test]
    fn load_and_lookup() {
        let dir = std::env::temp_dir().join(format!("ndpp_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_fixture(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("marginal_diag", 8, 4).expect("should resolve");
        assert_eq!(a.config, "m8_k2");
        assert_eq!(a.inputs[1].shape, vec![4, 4]);
        assert_eq!(a.outputs[0].dtype, "float32");
        assert!(m.find("marginal_diag", 16, 4).is_none());
        assert!(m.find_config("marginal_diag", "m8_k2").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_error_not_panic() {
        assert!(ArtifactManifest::load("/nonexistent/path").is_err());
    }
}
