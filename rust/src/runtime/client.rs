//! PJRT client wrapper: compile HLO-text artifacts once, execute many times.
//!
//! The `xla` crate (docs.rs/xla 0.1.6, binding xla_extension 0.5.1) parses
//! HLO **text** — the interchange format that survives the jax>=0.5
//! 64-bit-instruction-id proto incompatibility (see DESIGN.md and
//! /opt/xla-example/README.md).  Compiled executables are cached per
//! artifact path, so the request path pays compilation exactly once.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};
use once_cell::sync::OnceCell;

use crate::linalg::Matrix;

/// Process-wide PJRT CPU runtime with an executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

// xla::PjRtClient wraps a thread-safe C++ client; executables are likewise
// safe to share across threads for execution.
unsafe impl Send for XlaRuntime {}
unsafe impl Sync for XlaRuntime {}

static GLOBAL: OnceCell<XlaRuntime> = OnceCell::new();

impl XlaRuntime {
    fn new() -> Result<XlaRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Process-wide singleton (PJRT clients are heavyweight).
    pub fn global() -> Result<&'static XlaRuntime> {
        GLOBAL.get_or_try_init(XlaRuntime::new)
    }

    /// Compile (or fetch from cache) the HLO text at `path`.
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Execute a compiled artifact on host literals; returns the flattened
    /// output tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

// ---- literal conversion helpers ----------------------------------------

/// Row-major f32 literal from a [`Matrix`].
pub fn literal_matrix(m: &Matrix) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&m.to_f32());
    Ok(lit.reshape(&[m.rows as i64, m.cols as i64])?)
}

/// f32 vector literal.
pub fn literal_vec(v: &[f64]) -> xla::Literal {
    let f: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&f)
}

/// i32 matrix literal (for padded index batches).
pub fn literal_i32_matrix(rows: usize, cols: usize, data: &[i32]) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(&[rows as i64, cols as i64])?)
}

/// f32 scalar literal.
pub fn literal_scalar(x: f64) -> xla::Literal {
    xla::Literal::scalar(x as f32)
}

/// Extract an f32 literal into a `Vec<f64>`.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect())
}

/// Extract an f32 literal with known dims into a [`Matrix`].
pub fn literal_to_matrix(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let v = lit.to_vec::<f32>()?;
    anyhow::ensure!(v.len() == rows * cols, "literal size mismatch");
    Ok(Matrix::from_f32(rows, cols, &v))
}

/// Extract a scalar f32 literal.
pub fn literal_to_scalar(lit: &xla::Literal) -> Result<f64> {
    Ok(lit.get_first_element::<f32>()? as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let lit = literal_matrix(&m).unwrap();
        let back = literal_to_matrix(&lit, 2, 2).unwrap();
        assert_eq!(m, back);
        let v = literal_vec(&[1.5, -2.5]);
        assert_eq!(literal_to_vec(&v).unwrap(), vec![1.5, -2.5]);
        assert_eq!(literal_to_scalar(&literal_scalar(7.25)).unwrap(), 7.25);
    }
}
