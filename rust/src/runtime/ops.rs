//! Typed entry points over the AOT artifacts.
//!
//! `ModelOps` binds the artifact manifest to the PJRT runtime and exposes
//! the Layer-2 graphs as plain rust functions.  Lookups are shape-keyed:
//! callers pass matrices, `ModelOps` finds the artifact whose static shapes
//! match, or returns `None`-ish errors that callers treat as "fall back to
//! native".

use anyhow::{anyhow, Result};

use crate::linalg::Matrix;
use crate::runtime::artifact::{ArtifactManifest, ArtifactSpec};
use crate::runtime::client::{
    literal_i32_matrix, literal_matrix, literal_scalar, literal_to_matrix,
    literal_to_scalar, literal_to_vec, literal_vec, XlaRuntime,
};

/// High-level handle on the exported model graphs.
pub struct ModelOps {
    manifest: ArtifactManifest,
    runtime: &'static XlaRuntime,
}

impl ModelOps {
    /// Bind to a manifest directory.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<ModelOps> {
        let manifest = ArtifactManifest::load(dir)?;
        Ok(ModelOps { manifest, runtime: XlaRuntime::global()? })
    }

    /// Bind to `./artifacts` (or `NDPP_ARTIFACTS`) if present.
    pub fn discover() -> Option<ModelOps> {
        let manifest = ArtifactManifest::discover()?;
        let runtime = XlaRuntime::global().ok()?;
        Some(ModelOps { manifest, runtime })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// True if a sampler-side artifact set exists for shape `(m, k2)`.
    pub fn supports_sampling(&self, m: usize, k2: usize) -> bool {
        self.manifest.find("cholesky_sample", m, k2).is_some()
    }

    fn run(&self, spec: &ArtifactSpec, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.runtime.load(&spec.file)?;
        self.runtime.execute(&exe, inputs)
    }

    fn find(&self, name: &str, m: usize, k2: usize) -> Result<&ArtifactSpec> {
        self.manifest
            .find(name, m, k2)
            .ok_or_else(|| anyhow!("no '{name}' artifact for shape ({m}, {k2})"))
    }

    // ---- sampler-side graphs -------------------------------------------

    /// `diag(Z W Z^T)` via the Pallas `bilinear_diag` kernel.
    pub fn marginal_diag(&self, z: &Matrix, w: &Matrix) -> Result<Vec<f64>> {
        let spec = self.find("marginal_diag", z.rows, z.cols)?;
        let out = self.run(spec, &[literal_matrix(z)?, literal_matrix(w)?])?;
        literal_to_vec(&out[0])
    }

    /// `Z^T Z` via the Pallas `gram` kernel.
    pub fn gram(&self, z: &Matrix) -> Result<Matrix> {
        let spec = self.find("gram", z.rows, z.cols)?;
        let out = self.run(spec, &[literal_matrix(z)?])?;
        literal_to_matrix(&out[0], z.cols, z.cols)
    }

    /// Per-block outer-product sums (tree leaf construction).
    pub fn block_outer_sum(&self, z: &Matrix) -> Result<Vec<Matrix>> {
        let spec = self.find("block_outer_sum", z.rows, z.cols)?;
        let nb = spec.outputs[0].shape[0];
        let k2 = z.cols;
        let out = self.run(spec, &[literal_matrix(z)?])?;
        let flat = literal_to_vec(&out[0])?;
        anyhow::ensure!(flat.len() == nb * k2 * k2, "block_outer_sum size mismatch");
        Ok((0..nb)
            .map(|b| {
                Matrix::from_vec(k2, k2, flat[b * k2 * k2..(b + 1) * k2 * k2].to_vec())
            })
            .collect())
    }

    /// `(W, Z^T Z, logdet(L+I))` — sampler preprocessing in one call.
    pub fn preprocess(&self, z: &Matrix, x: &Matrix) -> Result<(Matrix, Matrix, f64)> {
        let spec = self.find("preprocess", z.rows, z.cols)?;
        let out = self.run(spec, &[literal_matrix(z)?, literal_matrix(x)?])?;
        let k2 = z.cols;
        Ok((
            literal_to_matrix(&out[0], k2, k2)?,
            literal_to_matrix(&out[1], k2, k2)?,
            literal_to_scalar(&out[2])?,
        ))
    }

    /// Full Algorithm-1 sweep on-device: `(mask, logp)` from uniforms `u`.
    pub fn cholesky_sample(
        &self,
        z: &Matrix,
        w: &Matrix,
        u: &[f64],
    ) -> Result<(Vec<usize>, f64)> {
        let spec = self.find("cholesky_sample", z.rows, z.cols)?;
        let out = self.run(
            spec,
            &[literal_matrix(z)?, literal_matrix(w)?, literal_vec(u)],
        )?;
        let mask = literal_to_vec(&out[0])?;
        let logp = literal_to_scalar(&out[1])?;
        let items = mask
            .iter()
            .enumerate()
            .filter(|&(_, &x)| x > 0.5)
            .map(|(i, _)| i)
            .collect();
        Ok((items, logp))
    }

    // ---- learning-side graphs -------------------------------------------

    /// Resolve the train-step artifact for `(m, k, batch, kmax)` if present.
    pub fn train_config(&self, m: usize, k: usize, bsz: usize, kmax: usize) -> Option<String> {
        let cfg = format!("m{m}_k{k}_b{bsz}_s{kmax}");
        self.manifest.find_config("train_step", &cfg).map(|_| cfg)
    }

    /// One Adam + projection step (see python/compile/train.py).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        cfg: &str,
        free: bool,
        v: &Matrix,
        b: &Matrix,
        raw_sigma: &[f64],
        m_state: &Matrix,
        v_state: &Matrix,
        t: f64,
        idx: (&[i32], usize, usize),
        mu: &[f64],
        alpha: f64,
        beta: f64,
        gamma: f64,
        lr: f64,
    ) -> Result<TrainStepOut> {
        let name = if free { "train_step_free" } else { "train_step" };
        let spec = self
            .manifest
            .find_config(name, cfg)
            .ok_or_else(|| anyhow!("no {name} artifact for config {cfg}"))?;
        let (idx_data, bsz, kmax) = idx;
        let out = self.run(
            spec,
            &[
                literal_matrix(v)?,
                literal_matrix(b)?,
                literal_vec(raw_sigma),
                literal_matrix(m_state)?,
                literal_matrix(v_state)?,
                literal_scalar(t),
                literal_i32_matrix(bsz, kmax, idx_data)?,
                literal_vec(mu),
                literal_scalar(alpha),
                literal_scalar(beta),
                literal_scalar(gamma),
                literal_scalar(lr),
            ],
        )?;
        let (m_rows, k) = (v.rows, v.cols);
        Ok(TrainStepOut {
            v: literal_to_matrix(&out[0], m_rows, k)?,
            b: literal_to_matrix(&out[1], m_rows, k)?,
            raw_sigma: literal_to_vec(&out[2])?,
            m_state: literal_to_matrix(&out[3], m_rows, 2 * k + 1)?,
            v_state: literal_to_matrix(&out[4], m_rows, 2 * k + 1)?,
            t: literal_to_scalar(&out[5])?,
            loss: literal_to_scalar(&out[6])?,
        })
    }

    /// Mean test log-likelihood of a padded batch.
    pub fn loglik_batch(
        &self,
        cfg: &str,
        v: &Matrix,
        b: &Matrix,
        raw_sigma: &[f64],
        idx: (&[i32], usize, usize),
    ) -> Result<f64> {
        let spec = self
            .manifest
            .find_config("loglik_batch", cfg)
            .ok_or_else(|| anyhow!("no loglik_batch artifact for config {cfg}"))?;
        let (idx_data, bsz, kmax) = idx;
        let out = self.run(
            spec,
            &[
                literal_matrix(v)?,
                literal_matrix(b)?,
                literal_vec(raw_sigma),
                literal_i32_matrix(bsz, kmax, idx_data)?,
            ],
        )?;
        literal_to_scalar(&out[0])
    }

    /// ONDPP constraint projection.
    pub fn project(&self, cfg: &str, v: &Matrix, b: &Matrix) -> Result<(Matrix, Matrix)> {
        let spec = self
            .manifest
            .find_config("project", cfg)
            .ok_or_else(|| anyhow!("no project artifact for config {cfg}"))?;
        let out = self.run(spec, &[literal_matrix(v)?, literal_matrix(b)?])?;
        Ok((
            literal_to_matrix(&out[0], v.rows, v.cols)?,
            literal_to_matrix(&out[1], b.rows, b.cols)?,
        ))
    }
}

/// Outputs of one train step.
#[derive(Debug, Clone)]
pub struct TrainStepOut {
    pub v: Matrix,
    pub b: Matrix,
    pub raw_sigma: Vec<f64>,
    pub m_state: Matrix,
    pub v_state: Matrix,
    pub t: f64,
    pub loss: f64,
}
