//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! rust request path (python never runs here).
//!
//! * [`artifact`] — `artifacts/manifest.json` parsing + shape-keyed lookup.
//! * [`client`] — thin wrapper over the `xla` crate's PJRT CPU client:
//!   `HloModuleProto::from_text_file -> XlaComputation -> compile ->
//!   execute` with typed literal conversion helpers.
//! * [`ops`] — high-level typed entry points (`marginal_diag`, `gram`,
//!   `cholesky_sample`, `train_step`, ...) used by samplers, the trainer,
//!   and the XLA-vs-native ablation bench.
//!
//! Everything here is optional at runtime: when `artifacts/` is absent the
//! library transparently uses the pure-rust implementations (the
//! coordinator logs which path is active).

pub mod artifact;
pub mod client;
pub mod ops;

pub use artifact::{ArtifactManifest, ArtifactSpec};
pub use client::XlaRuntime;
pub use ops::ModelOps;
