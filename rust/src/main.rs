//! `ndpp` — command-line entry point for the NDPP sampling framework.
//!
//! ```text
//! ndpp sample     draw samples from a kernel (cholesky | rejection | mcmc | dense)
//! ndpp complete   basket completion: condition on --given, rank + sample
//! ndpp serve      run the TCP sampling service
//! ndpp train      learn an ONDPP kernel (AOT/PJRT, or the native fallback)
//! ndpp promote    stage/promote a model version on a running server
//! ndpp rollback   move a served model back to its previous version
//! ndpp gen-data   generate a synthetic basket dataset
//! ndpp reproduce  regenerate a paper table/figure (table1|table2|table3|fig1|fig2)
//! ndpp info       environment + artifact status
//! ```

use std::sync::Arc;

use anyhow::{bail, Result};

use ndpp::bench::experiments::{self, ExpOptions};
use ndpp::bench::BenchRunner;
use ndpp::coordinator::server;
use ndpp::coordinator::{SamplerKind, SamplingService, ServiceConfig};
use ndpp::data::{recipes, synthetic, BasketDataset};
use ndpp::learn::{self, TrainConfig, Trainer};
use ndpp::ndpp::{MarginalKernel, Proposal};
use ndpp::rng::Xoshiro;
use ndpp::runtime::ModelOps;
use ndpp::sampler::{
    CholeskySampler, DenseCholeskySampler, McmcConfig, McmcSampler, ProposalKind,
    RejectionSampler, SampleTree, Sampler, TreeConfig,
};
use ndpp::util::args::{help_text, Args, Spec};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "sample" => cmd_sample(rest),
        "complete" => cmd_complete(rest),
        "serve" => cmd_serve(rest),
        "train" => cmd_train(rest),
        "promote" => cmd_promote(rest),
        "rollback" => cmd_rollback(rest),
        "gen-data" => cmd_gen_data(rest),
        "reproduce" => cmd_reproduce(rest),
        "map" => cmd_map(rest),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `ndpp help`)"),
    }
}

fn print_usage() {
    println!(
        "ndpp — scalable sampling for nonsymmetric determinantal point processes\n\
         (ICLR 2022 reproduction; see README.md)\n\n\
         commands:\n\
         \x20 sample     draw samples from a random/loaded kernel (--given conditions)\n\
         \x20 complete   basket completion: top next-item scores + conditional samples\n\
         \x20 serve      run the TCP sampling service\n\
         \x20 train      learn an ONDPP kernel (AOT via PJRT, or --native fallback)\n\
         \x20 promote    stage/promote a model version on a running server\n\
         \x20 rollback   move a served model back to its previous version\n\
         \x20 gen-data   generate a synthetic basket dataset\n\
         \x20 reproduce  regenerate a paper experiment (table1|table2|table3|fig1|fig2|mcmc|all)\n\
         \x20 map        greedy MAP inference (most-diverse set)\n\
         \x20 info       environment + artifact status\n\n\
         run `ndpp <command> --help` for options"
    );
}

/// Apply `--backend naive|blocked|simd` (process-wide) when given.
fn apply_backend_flag(a: &Args) -> Result<()> {
    if let Some(b) = a.get("backend") {
        ndpp::linalg::backend::set_active(ndpp::linalg::BackendKind::parse(b)?);
    }
    Ok(())
}

const BACKEND_HELP: &str =
    "linalg backend: naive | blocked | simd (default: $NDPP_BACKEND or blocked)";

const SAMPLE_SPECS: &[Spec] = &[
    Spec::opt("kernel", "load a saved kernel file instead of a random one"),
    Spec::opt_default("m", "4096", "ground-set size (random kernel)"),
    Spec::opt_default("k", "32", "per-part kernel rank K"),
    Spec::opt_default("n", "5", "number of samples"),
    Spec::opt_default("seed", "0", "rng seed"),
    Spec::opt_default("algo", "rejection", "cholesky | rejection | mcmc | dense | both | all"),
    Spec::opt(
        "given",
        "comma-separated observed items; samples are conditioned on containing them",
    ),
    Spec::opt_default("mcmc-proposal", "tree", MCMC_PROPOSAL_HELP),
    Spec::opt("backend", BACKEND_HELP),
    Spec::flag("help", "show help"),
];

const MCMC_PROPOSAL_HELP: &str =
    "mcmc item proposal: tree (O(log M) marginal-weighted descent) | uniform (oracle)";

/// Parse `--mcmc-proposal tree|uniform`.
fn parse_proposal_arg(a: &Args) -> Result<ProposalKind> {
    let s = a.str_or("mcmc-proposal", "tree");
    ProposalKind::parse(&s)
        .ok_or_else(|| anyhow::anyhow!("bad --mcmc-proposal '{s}' (tree | uniform)"))
}

/// Parse `--given 3,17,42` into item indices.
fn parse_given_arg(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|p| p.trim())
        .filter(|p| !p.is_empty())
        .map(|p| {
            p.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad --given item '{p}' (want an item index)"))
        })
        .collect()
}

fn cmd_sample(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, SAMPLE_SPECS)?;
    if a.flag("help") {
        print!("{}", help_text("sample", "draw NDPP samples", SAMPLE_SPECS));
        return Ok(());
    }
    apply_backend_flag(&a)?;
    let m = a.usize_or("m", 4096)?;
    let k = a.usize_or("k", 32)?;
    let n = a.usize_or("n", 5)?;
    let seed = a.u64_or("seed", 0)?;
    let algo = a.str_or("algo", "rejection");
    if !["cholesky", "rejection", "mcmc", "dense", "both", "all"].contains(&algo.as_str()) {
        bail!("unknown --algo '{algo}' (cholesky | rejection | mcmc | dense | both | all)");
    }

    let mut rng = Xoshiro::seeded(seed);
    let kernel = match a.get("kernel") {
        Some(path) => {
            let k = ndpp::ndpp::NdppKernel::load(path)?;
            println!("loaded kernel from {path}: M={}, 2K={}", k.m(), 2 * k.k());
            k
        }
        None => {
            println!("random ONDPP kernel: M={m}, 2K={}", 2 * k);
            experiments::tablelike_kernel(m, k, &mut rng)
        }
    };

    let given = match a.get("given") {
        Some(g) => parse_given_arg(g)?,
        None => Vec::new(),
    };
    let proposal_kind = parse_proposal_arg(&a)?;
    if !given.is_empty() {
        return sample_conditional(&kernel, &given, &algo, n, proposal_kind, &rng);
    }

    if algo == "cholesky" || algo == "both" || algo == "all" {
        let mut s = CholeskySampler::new(&kernel);
        let mut r = rng.split(1);
        for i in 0..n {
            let (y, lp) = s.sample_with_logprob(&mut r);
            println!("cholesky[{i}] (logp {lp:.2}): {y:?}");
        }
    }
    if algo == "rejection" || algo == "both" || algo == "all" {
        let proposal = Proposal::build(&kernel);
        let spectral = proposal.spectral();
        let tree = SampleTree::build(&spectral, TreeConfig::default());
        let mut s = RejectionSampler::new(&kernel, &proposal, &tree);
        let mut r = rng.split(2);
        for i in 0..n {
            let y = s.sample(&mut r);
            println!("rejection[{i}] ({} proposals): {y:?}", s.last_proposals);
        }
        println!(
            "rejection rate: observed {:.2}, expected {:.2}",
            s.observed_rejection_rate(),
            s.expected_rejection_rate()
        );
    }
    if algo == "mcmc" || algo == "all" {
        let mut config = McmcConfig::for_kernel(&kernel);
        config.proposal = proposal_kind;
        let proposal = Proposal::build(&kernel);
        let tree = SampleTree::build(&proposal.spectral(), TreeConfig::default());
        let mut s = McmcSampler::new(&kernel, config).with_tree(&tree);
        let mut r = rng.split(3);
        // one chain for the whole batch: burn-in amortized, thinned draws
        for (i, y) in s.sample_chain(n, &mut r).into_iter().enumerate() {
            println!("mcmc[{i}] (|Y| = {}): {y:?}", y.len());
        }
        println!(
            "mcmc: size {} | burn-in {} (adaptive: {}) | thinning {} | proposal {} | \
             acceptance {:.2}",
            config.size,
            s.last_burn_in,
            config.adaptive_burn_in,
            config.thinning,
            s.proposal_kind().as_str(),
            s.acceptance_rate()
        );
    }
    if algo == "dense" || algo == "all" {
        if kernel.m() > SamplerKind::DENSE_MAX_M {
            println!(
                "dense: skipped — O(M^3) per sample is capped at M <= {} (M = {})",
                SamplerKind::DENSE_MAX_M,
                kernel.m()
            );
        } else {
            let mut s = DenseCholeskySampler::new(&kernel);
            let mut r = rng.split(4);
            for i in 0..n {
                println!("dense[{i}]: {:?}", s.sample(&mut r));
            }
        }
    }
    Ok(())
}

/// `ndpp sample --given …` — conditional sampling through the
/// basket-completion subsystem: condition once, then drive the requested
/// sampler families from the shared conditioned state.
fn sample_conditional(
    kernel: &ndpp::ndpp::NdppKernel,
    given: &[usize],
    algo: &str,
    n: usize,
    proposal_kind: ProposalKind,
    rng: &Xoshiro,
) -> Result<()> {
    use ndpp::sampler::{ConditionalPrepared, ConditionalScratch};
    let marginal = MarginalKernel::build(kernel);
    let proposal = Proposal::build(kernel);
    let tree = SampleTree::build(&proposal.spectral(), TreeConfig::default());
    let prep = ConditionalPrepared::build(kernel, &marginal, &tree);
    let mut scratch = ConditionalScratch::new();
    scratch
        .condition(&prep, &marginal.z, given)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "conditioned on {:?} (E[completion size] = {:.2})",
        scratch.given(),
        scratch.expected_completion_size(&prep)
    );
    if algo == "cholesky" || algo == "both" || algo == "all" {
        let mut r = rng.split(1);
        for i in 0..n {
            let (y, lp) = scratch.sample_cholesky(&marginal.z, &mut r);
            println!("cholesky[{i}] (logp {lp:.2}): {y:?}");
        }
    }
    if algo == "rejection" || algo == "both" || algo == "all" {
        scratch.ensure_rejection(&prep, &tree);
        let mut r = rng.split(2);
        for i in 0..n {
            let y = scratch.sample_rejection(&marginal.z, &tree, &mut r);
            println!("rejection[{i}] ({} proposals): {y:?}", scratch.last_proposals);
        }
        println!("conditional E[rejections]: {:.2}", scratch.expected_rejections());
    }
    if algo == "mcmc" || algo == "all" {
        scratch.set_mcmc_proposal(proposal_kind);
        scratch.ensure_mcmc(&prep, &marginal.z, kernel);
        let mut r = rng.split(3);
        for i in 0..n {
            let (y, _) = scratch.sample_mcmc(kernel, &tree, &mut r);
            println!("mcmc[{i}] (|Y| = {}): {y:?}", y.len());
        }
        let cfg = scratch.mcmc_config();
        let (steps, accepts, _expected) = scratch.take_mcmc_stats();
        println!(
            "mcmc: completion size {} | burn-in cap {} | proposal {} | acceptance {:.2}",
            cfg.size,
            cfg.burn_in,
            scratch.mcmc_proposal_kind().as_str(),
            if steps == 0 { 0.0 } else { accepts as f64 / steps as f64 }
        );
    }
    if algo == "dense" || algo == "all" {
        println!("dense: conditioning is not supported (use cholesky | rejection | mcmc)");
    }
    Ok(())
}

const COMPLETE_SPECS: &[Spec] = &[
    Spec::opt("kernel", "load a saved kernel file instead of a random one"),
    Spec::opt_default("m", "4096", "ground-set size (random kernel)"),
    Spec::opt_default("k", "32", "per-part kernel rank K"),
    Spec::opt("given", "comma-separated observed basket (required)"),
    Spec::opt_default("top", "10", "how many top-scoring completions to rank"),
    Spec::opt_default("n", "3", "how many conditional set samples to draw"),
    Spec::opt_default("algo", "cholesky", "cholesky | rejection | mcmc (set sampler)"),
    Spec::opt_default("mcmc-proposal", "tree", MCMC_PROPOSAL_HELP),
    Spec::opt_default("seed", "0", "rng seed"),
    Spec::opt("backend", BACKEND_HELP),
    Spec::flag("help", "show help"),
];

/// `ndpp complete` — the basket-completion surface: rank every catalog
/// item by its next-item score `det(L_{J ∪ i})/det(L_J)` and draw a few
/// full conditional sets alongside.
fn cmd_complete(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, COMPLETE_SPECS)?;
    if a.flag("help") {
        print!(
            "{}",
            help_text("complete", "rank + sample basket completions", COMPLETE_SPECS)
        );
        return Ok(());
    }
    apply_backend_flag(&a)?;
    let Some(gstr) = a.get("given") else {
        bail!("--given is required (e.g. --given 3,17,42)");
    };
    let given = parse_given_arg(gstr)?;
    if given.is_empty() {
        bail!("--given must name at least one observed item");
    }
    let seed = a.u64_or("seed", 0)?;
    let mut rng = Xoshiro::seeded(seed);
    let kernel = match a.get("kernel") {
        Some(path) => {
            let k = ndpp::ndpp::NdppKernel::load(path)?;
            println!("loaded kernel from {path}: M={}, 2K={}", k.m(), 2 * k.k());
            k
        }
        None => {
            let (m, k) = (a.usize_or("m", 4096)?, a.usize_or("k", 32)?);
            println!("random ONDPP kernel: M={m}, 2K={}", 2 * k);
            experiments::tablelike_kernel(m, k, &mut rng)
        }
    };

    use ndpp::ndpp::ConditionedKernel;
    let z = kernel.z();
    let cond = ConditionedKernel::build(&kernel, &given).map_err(|e| anyhow::anyhow!("{e}"))?;
    let scores = cond.scores(&z);
    let mut ranked: Vec<(usize, f64)> = scores
        .iter()
        .enumerate()
        .filter(|(i, _)| !cond.given().contains(i))
        .map(|(i, &s)| (i, s))
        .collect();
    ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
    let top = a.usize_or("top", 10)?;
    println!("\ntop {} completions of {:?}:", top.min(ranked.len()), cond.given());
    for (rank, (item, score)) in ranked.iter().take(top).enumerate() {
        println!("  #{:<3} item {:<8} score {:.6}", rank + 1, item, score);
    }

    let n = a.usize_or("n", 3)?;
    if n > 0 {
        let algo = a.str_or("algo", "cholesky");
        if !["cholesky", "rejection", "mcmc"].contains(&algo.as_str()) {
            bail!("unknown --algo '{algo}' (cholesky | rejection | mcmc)");
        }
        println!("\nsampled completions ({algo}):");
        sample_conditional(&kernel, &given, &algo, n, parse_proposal_arg(&a)?, &rng)?;
    }
    Ok(())
}

const SERVE_SPECS: &[Spec] = &[
    Spec::opt_default("addr", "127.0.0.1:7433", "listen address"),
    Spec::opt_default("models", "demo:4096:32", "comma list of name:M:K random models"),
    Spec::opt_default(
        "shards",
        "0",
        "shard worker threads (0 = auto: the thread-budget split, see `ndpp info`)",
    ),
    Spec::opt_default(
        "queue-depth",
        "1024",
        "bound per (model, shard) queue; overflow rejects with queue_full",
    ),
    Spec::opt_default(
        "deadline-ms",
        "0",
        "default per-request deadline in milliseconds (0 = none)",
    ),
    Spec::opt_default(
        "cache-bytes",
        "67108864",
        "byte budget for the hot-basket conditioning cache (0 = disable)",
    ),
    Spec::opt_default(
        "steer-threshold",
        "10000",
        "expected proposals/sample above which algo=auto conditionals steer to mcmc",
    ),
    Spec::opt_default(
        "canary-fraction",
        "0",
        "fraction of bare-alias traffic served by a staged canary version (0..1)",
    ),
    Spec::opt_default("mcmc-proposal", "tree", MCMC_PROPOSAL_HELP),
    Spec::opt_default(
        "slow-log",
        "32",
        "worst-N slow-trace retention budget for the `slow` op (0 = disable)",
    ),
    Spec::opt_default("seed", "0", "rng seed for model generation"),
    Spec::opt("backend", BACKEND_HELP),
    Spec::flag("help", "show help"),
];

fn cmd_serve(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, SERVE_SPECS)?;
    if a.flag("help") {
        print!("{}", help_text("serve", "run the sampling service", SERVE_SPECS));
        return Ok(());
    }
    let mut config = ServiceConfig {
        shards: a.usize_or("shards", 0)?,
        queue_depth: a.usize_or("queue-depth", 1024)?,
        conditioning_cache_bytes: a.usize_or(
            "cache-bytes",
            ndpp::coordinator::service::DEFAULT_CONDITIONING_CACHE_BYTES,
        )?,
        steer_threshold: a.f64_or(
            "steer-threshold",
            ndpp::coordinator::service::DEFAULT_STEER_THRESHOLD,
        )?,
        canary_fraction: a.f64_or("canary-fraction", 0.0)?,
        mcmc_proposal: parse_proposal_arg(&a)?,
        slow_log: a.usize_or("slow-log", ndpp::coordinator::service::DEFAULT_SLOW_LOG)?,
        ..Default::default()
    };
    let deadline_ms = a.u64_or("deadline-ms", 0)?;
    if deadline_ms > 0 {
        config.deadline = Some(std::time::Duration::from_millis(deadline_ms));
    }
    if let Some(b) = a.get("backend") {
        config.backend = Some(ndpp::linalg::BackendKind::parse(b)?);
    }
    let service = Arc::new(SamplingService::new(config));
    println!(
        "serving with {} shard workers, queue depth {}, deadline {}, \
         conditioning cache {}, steer threshold {:.0}, mcmc proposal {}, \
         canary fraction {:.2}, slow log {}",
        service.shards(),
        service.config().queue_depth,
        service
            .config()
            .deadline
            .map(|d| format!("{} ms", d.as_millis()))
            .unwrap_or_else(|| "none".into()),
        if service.conditioning_cache().enabled() {
            format!("{} B", service.conditioning_cache().budget())
        } else {
            "off".into()
        },
        service.config().steer_threshold,
        service.config().mcmc_proposal.as_str(),
        service.config().canary_fraction,
        service.slow_ring().budget()
    );
    let seed = a.u64_or("seed", 0)?;
    let mut rng = Xoshiro::seeded(seed);
    for spec in a.str_or("models", "demo:4096:32").split(',') {
        let parts: Vec<&str> = spec.trim().split(':').collect();
        match parts.as_slice() {
            [name, path] => {
                // name:path — load a saved kernel
                let kernel = ndpp::ndpp::NdppKernel::load(path)?;
                println!("registering {name} from {path} (M={})...", kernel.m());
                service.register(name, kernel);
            }
            [name, m, k] => {
                let (m, k): (usize, usize) = (m.parse()?, k.parse()?);
                println!("registering {name} (random ONDPP, M={m}, K={k})...");
                service.register(name, experiments::tablelike_kernel(m, k, &mut rng));
            }
            _ => bail!("bad model spec '{spec}' (want name:M:K or name:path)"),
        }
    }
    let addr = a.str_or("addr", "127.0.0.1:7433");
    println!(
        "listening on {addr} (line-delimited JSON; op=sample|batch|models|metrics|\
         slow|versions|register|promote|rollback|ping|shutdown)"
    );
    server::serve(service, &addr, |bound| println!("bound {bound}"))
}

const TRAIN_SPECS: &[Spec] = &[
    Spec::opt("data", "dataset file (ndpp-baskets format); default: synthetic"),
    Spec::opt("out", "save the learned kernel to this path"),
    Spec::opt_default("steps", "200", "training steps"),
    Spec::opt_default("gamma", "0.1", "rejection-rate regularizer"),
    Spec::opt_default("lr", "0.05", "Adam learning rate"),
    Spec::opt_default("k", "32", "per-part kernel rank K (native trainer only)"),
    Spec::opt_default("batch", "64", "minibatch size (native trainer only)"),
    Spec::opt_default("seed", "0", "rng seed"),
    Spec::flag("free", "unconstrained NDPP (no orthogonality projection)"),
    Spec::flag(
        "native",
        "force the pure-rust trainer even when AOT artifacts are present",
    ),
    Spec::flag("help", "show help"),
];

fn cmd_train(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, TRAIN_SPECS)?;
    if a.flag("help") {
        print!("{}", help_text("train", "learn an ONDPP kernel", TRAIN_SPECS));
        return Ok(());
    }
    let ops = if a.flag("native") { None } else { ModelOps::discover() };
    let Some(ops) = ops else {
        if !a.flag("native") {
            println!("artifacts/ not found — using the native pure-rust trainer");
        }
        return cmd_train_native(&a);
    };
    // trainable shape config (see python/compile/aot.py CONFIGS)
    let (m, k, bsz, kmax) = (2048usize, 32usize, 64usize, 16usize);

    let ds = load_or_synthesize_train_data(&a, m)?;
    anyhow::ensure!(ds.m == m, "dataset M={} but artifacts are built for M={m}", ds.m);
    let mut ds = ds;
    ds.trim(kmax);
    let mut rng = Xoshiro::seeded(a.u64_or("seed", 0)?);
    let split = ds.split(100, 400, &mut rng);
    let mu = ds.item_frequencies();

    let tc = TrainConfig {
        k,
        batch_size: bsz,
        kmax,
        steps: a.usize_or("steps", 200)?,
        lr: a.f64_or("lr", 0.05)?,
        gamma: a.f64_or("gamma", 0.1)?,
        project: !a.flag("free"),
        seed: a.u64_or("seed", 0)?,
        ..Default::default()
    };
    println!("training (AOT/PJRT): {tc:?}");
    let trainer = Trainer::new(&ops, m, split.train.clone(), mu, tc)?;
    let model = trainer.run(|step, loss| {
        if step % 20 == 0 {
            println!("step {step:>5}  loss {loss:.4}");
        }
    })?;
    report_and_save(&a, &model, &split.test)
}

/// The `ndpp train` fallback: the pure-rust [`learn::NativeTrainer`], no
/// artifacts or PJRT required — this is the path the zero-downtime
/// lifecycle (train → register canary → gated promote) uses on a serving
/// host with no AOT toolchain.
fn cmd_train_native(a: &Args) -> Result<()> {
    let kmax = 16usize;
    let mut ds = load_or_synthesize_train_data(a, 2048)?;
    ds.trim(kmax);
    let n = ds.baskets.len();
    let (n_val, n_test) = ((n / 20).clamp(1, 100), (n / 5).clamp(1, 400));
    let mut rng = Xoshiro::seeded(a.u64_or("seed", 0)?);
    let split = ds.split(n_val, n_test, &mut rng);
    let mu = ds.item_frequencies();

    let tc = TrainConfig {
        k: a.usize_or("k", 32)?,
        batch_size: a.usize_or("batch", 64)?,
        kmax,
        steps: a.usize_or("steps", 200)?,
        lr: a.f64_or("lr", 0.05)?,
        gamma: a.f64_or("gamma", 0.1)?,
        project: !a.flag("free"),
        seed: a.u64_or("seed", 0)?,
        ..Default::default()
    };
    println!("training (native, M={}): {tc:?}", ds.m);
    let trainer = learn::NativeTrainer::new(ds.m, split.train.clone(), mu, tc)?;
    let model = trainer.run(|step, loss| {
        if step % 20 == 0 {
            println!("step {step:>5}  loss {loss:.4}");
        }
    })?;
    report_and_save(a, &model, &split.test)
}

/// `--data` file, or the synthetic uk_retail-like default at catalog
/// size `m`.
fn load_or_synthesize_train_data(a: &Args, m: usize) -> Result<BasketDataset> {
    match a.get("data") {
        Some(path) => BasketDataset::load(path),
        None => {
            println!("no --data given; generating uk_retail-like synthetic data at M={m}");
            let recipe = recipes::dataset_by_name("uk_retail_synth", "fast").unwrap();
            let mut cfg = recipe.config.clone();
            cfg.m = m;
            cfg.n_baskets = 2500;
            let mut rng = Xoshiro::seeded(a.u64_or("seed", 0)?);
            Ok(synthetic::generate_baskets(&cfg, &mut rng))
        }
    }
}

/// Shared tail of both trainers: §6.1 metrics on the held-out split,
/// then `--out` checkpoint.
fn report_and_save(a: &Args, model: &ndpp::learn::TrainedModel, test: &[Vec<usize>]) -> Result<()> {
    let mk = MarginalKernel::build(&model.kernel);
    let mut eval_rng = Xoshiro::seeded(1);
    let mpr = learn::mpr(&model.kernel, test, &mut eval_rng);
    let auc = learn::auc(&model.kernel, mk.logdet_l_plus_i, test, &mut eval_rng);
    let ll = learn::test_loglik(&model.kernel, mk.logdet_l_plus_i, test);
    let rej = Proposal::build(&model.kernel).expected_rejections();
    println!("\nfinal: MPR {mpr:.2}  AUC {auc:.3}  test-loglik {ll:.3}  E[rejections] {rej:.2}");
    if let Some(out) = a.get("out") {
        model.kernel.save(out)?;
        println!("kernel saved to {out}");
    }
    Ok(())
}

const PROMOTE_SPECS: &[Spec] = &[
    Spec::opt_default("addr", "127.0.0.1:7433", "server address"),
    Spec::opt("model", "model family name (required)"),
    Spec::opt(
        "kernel",
        "register this saved kernel (path on the server's host) as a canary first",
    ),
    Spec::opt("version", "explicit version to promote (default: the staged canary)"),
    Spec::opt(
        "data",
        "held-out ndpp-baskets file (server-side path): gate the promotion on \
         MPR/AUC non-regression vs the live version",
    ),
    Spec::opt_default("eval-seed", "0", "seed for the gate's evaluation streams"),
    Spec::flag("stage-only", "register the canary and stop without promoting"),
    Spec::flag("help", "show help"),
];

/// `ndpp promote` — the operator's rollout verb: optionally stage a
/// kernel file as a canary, then move the serving alias to it (gated on
/// held-out MPR/AUC when `--data` is given).  The swap is atomic:
/// in-flight requests finish on the version they resolved.
fn cmd_promote(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, PROMOTE_SPECS)?;
    if a.flag("help") {
        print!("{}", help_text("promote", "stage/promote a model version", PROMOTE_SPECS));
        return Ok(());
    }
    let Some(model) = a.get("model") else {
        bail!("--model is required");
    };
    let addr = a.str_or("addr", "127.0.0.1:7433");
    let mut client = server::Client::connect(&addr)?;
    let mut version: Option<u64> = match a.get("version") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    if let Some(kpath) = a.get("kernel") {
        let v = client.register_model(model, kpath, true)?;
        println!("staged canary {model}@{v} from {kpath}");
        version = Some(v);
        if a.flag("stage-only") {
            println!("(stage-only: promote later with `ndpp promote --model {model}`)");
            return Ok(());
        }
    }
    let resp = client.promote(model, version, a.get("data").map(|s| s.as_str()), a.u64_or("eval-seed", 0)?)?;
    let v = resp.get("version").and_then(|v| v.as_u64()).unwrap_or(0);
    match resp.get("gate") {
        Some(gate) => {
            let c = gate.get("candidate").cloned().unwrap_or(ndpp::util::json::Json::obj());
            let l = gate.get("live").cloned().unwrap_or(ndpp::util::json::Json::obj());
            println!(
                "promoted {model}@{v} (gate passed: candidate MPR {:.2} AUC {:.3} vs \
                 live MPR {:.2} AUC {:.3})",
                c.f64_or("mpr", f64::NAN),
                c.f64_or("auc", f64::NAN),
                l.f64_or("mpr", f64::NAN),
                l.f64_or("auc", f64::NAN),
            );
        }
        None => println!("promoted {model}@{v} (ungated)"),
    }
    Ok(())
}

const ROLLBACK_SPECS: &[Spec] = &[
    Spec::opt_default("addr", "127.0.0.1:7433", "server address"),
    Spec::opt("model", "model family name (required)"),
    Spec::flag("help", "show help"),
];

/// `ndpp rollback` — move the serving alias back to the previous live
/// version (the rolled-back version stays pinnable as `name@N`).
fn cmd_rollback(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, ROLLBACK_SPECS)?;
    if a.flag("help") {
        print!("{}", help_text("rollback", "roll a model back one version", ROLLBACK_SPECS));
        return Ok(());
    }
    let Some(model) = a.get("model") else {
        bail!("--model is required");
    };
    let addr = a.str_or("addr", "127.0.0.1:7433");
    let mut client = server::Client::connect(&addr)?;
    let v = client.rollback(model)?;
    println!("rolled back: {model} now serves version {v}");
    Ok(())
}

const GEN_SPECS: &[Spec] = &[
    Spec::opt_default("dataset", "uk_retail_synth", "recipe name"),
    Spec::opt_default("out", "data.baskets", "output path"),
    Spec::opt_default("profile", "fast", "fast | paper"),
    Spec::opt_default("seed", "0", "rng seed"),
    Spec::flag("help", "show help"),
];

fn cmd_gen_data(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, GEN_SPECS)?;
    if a.flag("help") {
        print!("{}", help_text("gen-data", "generate synthetic baskets", GEN_SPECS));
        return Ok(());
    }
    let name = a.str_or("dataset", "uk_retail_synth");
    let profile = a.str_or("profile", "fast");
    let recipe = recipes::dataset_by_name(&name, &profile)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{name}'"))?;
    let ds = recipe.generate(a.u64_or("seed", 0)?);
    let out = a.str_or("out", "data.baskets");
    ds.save(&out)?;
    println!(
        "wrote {} baskets over M={} to {out} (mean size {:.1})",
        ds.baskets.len(),
        ds.m,
        ds.mean_basket_size()
    );
    Ok(())
}

const REPRO_SPECS: &[Spec] = &[
    Spec::opt_default("exp", "all", "table1|table2|table3|fig1|fig2|mcmc|all"),
    Spec::opt_default("profile", "fast", "fast | paper"),
    Spec::opt_default("k", "32", "per-part rank for sampling experiments"),
    Spec::opt_default("seed", "0", "rng seed"),
    Spec::opt("backend", BACKEND_HELP),
    Spec::flag("help", "show help"),
];

fn cmd_reproduce(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, REPRO_SPECS)?;
    if a.flag("help") {
        print!("{}", help_text("reproduce", "regenerate paper experiments", REPRO_SPECS));
        return Ok(());
    }
    apply_backend_flag(&a)?;
    let opts = ExpOptions {
        profile: a.str_or("profile", "fast"),
        seed: a.u64_or("seed", 0)?,
        k: a.usize_or("k", 32)?,
        runner: if a.str_or("profile", "fast") == "paper" {
            BenchRunner::default()
        } else {
            BenchRunner::quick()
        },
    };
    let exp = a.str_or("exp", "all");
    let needs_ops = matches!(exp.as_str(), "table2" | "fig1" | "all");
    let ops = if needs_ops {
        Some(ModelOps::discover().ok_or_else(|| {
            anyhow::anyhow!("artifacts/ required for {exp} — run `make artifacts`")
        })?)
    } else {
        None
    };
    match exp.as_str() {
        "table1" => experiments::table1(&opts).map(|_| ()),
        "table2" => experiments::table2(&opts, ops.as_ref().unwrap()).map(|_| ()),
        "table3" => experiments::table3(&opts).map(|_| ()),
        "fig1" => experiments::fig1(&opts, ops.as_ref().unwrap()).map(|_| ()),
        "fig2" => experiments::fig2(&opts).map(|_| ()),
        "mcmc" => experiments::mcmc_comparison(&opts).map(|_| ()),
        "all" => {
            experiments::table1(&opts)?;
            experiments::table3(&opts)?;
            experiments::fig2(&opts)?;
            experiments::mcmc_comparison(&opts)?;
            let ops = ops.as_ref().unwrap();
            experiments::table2(&opts, ops)?;
            experiments::fig1(&opts, ops)?;
            Ok(())
        }
        other => bail!("unknown experiment '{other}'"),
    }
}

const MAP_SPECS: &[Spec] = &[
    Spec::opt("kernel", "saved kernel file (default: random)"),
    Spec::opt_default("m", "4096", "ground-set size (random kernel)"),
    Spec::opt_default("k", "32", "per-part rank K"),
    Spec::opt_default("budget", "10", "max set size"),
    Spec::opt_default("seed", "0", "rng seed"),
    Spec::flag("help", "show help"),
];

fn cmd_map(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, MAP_SPECS)?;
    if a.flag("help") {
        print!("{}", help_text("map", "greedy MAP inference", MAP_SPECS));
        return Ok(());
    }
    let kernel = match a.get("kernel") {
        Some(path) => ndpp::ndpp::NdppKernel::load(path)?,
        None => {
            let mut rng = Xoshiro::seeded(a.u64_or("seed", 0)?);
            experiments::tablelike_kernel(a.usize_or("m", 4096)?, a.usize_or("k", 32)?, &mut rng)
        }
    };
    // min_gain 0 fills the budget (gain>1 would require det-increasing
    // items, rare for normalized recommendation kernels)
    let r = ndpp::learn::greedy_map(&kernel, a.usize_or("budget", 10)?, 0.0);
    println!("MAP set ({} items, log det {:.3}): {:?}", r.items.len(), r.log_det, r.items);
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("ndpp {} — three-layer rust+jax+pallas NDPP sampling", env!("CARGO_PKG_VERSION"));
    let budget = ndpp::linalg::backend::thread_budget();
    println!(
        "cores: {} ({})",
        budget.cores,
        if budget.explicit {
            "split pinned by NDPP_BACKEND_THREADS"
        } else {
            "auto split; NDPP_BACKEND_THREADS to pin"
        }
    );
    println!(
        "linalg backend: {} ({} threads per op = persistent pool of {} + caller; \
         NDPP_BACKEND / --backend to change)",
        ndpp::linalg::backend::active_kind().as_str(),
        budget.backend,
        budget.pool_workers
    );
    println!("serving shards (default): {}", budget.shards);
    let serving = ndpp::coordinator::ServiceConfig::default();
    println!(
        "serving steering (default): steer threshold {:.0} expected proposals/sample \
         (--steer-threshold), mcmc proposal {} (--mcmc-proposal tree|uniform)",
        serving.steer_threshold,
        serving.mcmc_proposal.as_str()
    );
    println!(
        "serving mcmc chains: steered auto runs the variable-size up/down/swap chain, \
         pinned mcmc the fixed-size swap chain; burn-in is adaptive (lag-1 \
         autocorrelation), bounded by the per-model McmcConfig"
    );
    println!(
        "simd ISA: {} (runtime-detected, NDPP_SIMD_ISA to override; `simd` backend \
         falls back avx512 -> avx2 -> portable / neon when a tier is missing)",
        ndpp::linalg::backend::simd_isa().as_str()
    );
    match ModelOps::discover() {
        Some(ops) => {
            println!("artifacts: {} found:", ops.manifest().artifacts.len());
            for a in &ops.manifest().artifacts {
                println!("  {:<18} {:<22} {}", a.name, a.config, a.file.display());
            }
        }
        None => println!("artifacts: NOT FOUND (run `make artifacts`; native fallbacks active)"),
    }
    match ndpp::runtime::XlaRuntime::global() {
        Ok(_) => println!("pjrt: cpu client OK"),
        Err(e) => println!("pjrt: UNAVAILABLE ({e})"),
    }
    Ok(())
}
