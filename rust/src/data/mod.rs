//! Datasets: basket (subset) collections over an item catalog.
//!
//! The paper evaluates on five real recommendation datasets (UK Retail,
//! Recipe, Instacart, Million Song, Book).  Those are not redistributable /
//! downloadable in this environment, so [`recipes`] provides synthetic
//! stand-ins with matched statistics (catalog size, power-law item
//! popularity, Poisson basket sizes, latent-cluster co-occurrence);
//! DESIGN.md §4 documents the substitution.  [`synthetic`] also implements
//! the Han & Gillenwater (2020) feature generator used verbatim by the
//! paper's §6.2 synthetic timing experiments.

pub mod baskets;
pub mod recipes;
pub mod synthetic;

pub use baskets::{BasketDataset, Split};
pub use recipes::{dataset_by_name, standard_datasets, DatasetRecipe};
pub use synthetic::BasketGenConfig;
