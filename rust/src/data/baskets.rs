//! Basket dataset container, text serialization, and train/val/test splits.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::rng::Xoshiro;

/// A collection of baskets (subsets of `[0, m)`).
#[derive(Debug, Clone)]
pub struct BasketDataset {
    pub name: String,
    /// catalog size
    pub m: usize,
    pub baskets: Vec<Vec<usize>>,
}

/// Train/validation/test views into a dataset.
#[derive(Debug, Clone)]
pub struct Split {
    pub train: Vec<Vec<usize>>,
    pub val: Vec<Vec<usize>>,
    pub test: Vec<Vec<usize>>,
}

impl BasketDataset {
    pub fn new(name: impl Into<String>, m: usize, baskets: Vec<Vec<usize>>) -> Self {
        let ds = BasketDataset { name: name.into(), m, baskets };
        debug_assert!(ds.validate().is_ok());
        ds
    }

    /// Every item id must be in range and baskets must be duplicate-free.
    pub fn validate(&self) -> Result<()> {
        for (bi, b) in self.baskets.iter().enumerate() {
            let mut seen = vec![];
            for &i in b {
                if i >= self.m {
                    bail!("basket {bi}: item {i} out of range (m={})", self.m);
                }
                seen.push(i);
            }
            seen.sort_unstable();
            seen.dedup();
            if seen.len() != b.len() {
                bail!("basket {bi}: duplicate items");
            }
        }
        Ok(())
    }

    /// Per-item occurrence counts, floored at 1 (the `mu_i` regularizer
    /// weights of Eq. (14)).
    pub fn item_frequencies(&self) -> Vec<f64> {
        let mut mu = vec![0.0f64; self.m];
        for b in &self.baskets {
            for &i in b {
                mu[i] += 1.0;
            }
        }
        for x in &mut mu {
            *x = x.max(1.0);
        }
        mu
    }

    /// Largest basket size (the paper sets K to this, Appendix C).
    pub fn max_basket_size(&self) -> usize {
        self.baskets.iter().map(|b| b.len()).max().unwrap_or(0)
    }

    pub fn mean_basket_size(&self) -> f64 {
        if self.baskets.is_empty() {
            return 0.0;
        }
        self.baskets.iter().map(|b| b.len()).sum::<usize>() as f64
            / self.baskets.len() as f64
    }

    /// Random split mirroring the paper's Appendix B: `n_val` + `n_test`
    /// random baskets held out, rest train.
    pub fn split(&self, n_val: usize, n_test: usize, rng: &mut Xoshiro) -> Split {
        let n = self.baskets.len();
        assert!(n_val + n_test < n, "not enough baskets to split");
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let val = idx[..n_val].iter().map(|&i| self.baskets[i].clone()).collect();
        let test = idx[n_val..n_val + n_test]
            .iter()
            .map(|&i| self.baskets[i].clone())
            .collect();
        let train = idx[n_val + n_test..]
            .iter()
            .map(|&i| self.baskets[i].clone())
            .collect();
        Split { train, val, test }
    }

    /// Drop baskets larger than `max` (the paper trims baskets > 100).
    pub fn trim(&mut self, max: usize) {
        self.baskets.retain(|b| b.len() <= max && !b.is_empty());
    }

    // ---- serialization ---------------------------------------------------
    // line 1: "ndpp-baskets <m> <name>"; then one basket per line,
    // space-separated item ids.

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut out = format!("ndpp-baskets {} {}\n", self.m, self.name);
        for b in &self.baskets {
            let line: Vec<String> = b.iter().map(|i| i.to_string()).collect();
            out.push_str(&line.join(" "));
            out.push('\n');
        }
        std::fs::write(path.as_ref(), out)
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<BasketDataset> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty dataset file")?;
        let mut parts = header.split_whitespace();
        if parts.next() != Some("ndpp-baskets") {
            bail!("bad dataset header");
        }
        let m: usize = parts.next().context("missing m")?.parse()?;
        let name = parts.next().unwrap_or("unnamed").to_string();
        let mut baskets = Vec::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let b: Vec<usize> = line
                .split_whitespace()
                .map(|t| t.parse::<usize>().context("bad item id"))
                .collect::<Result<_>>()?;
            baskets.push(b);
        }
        let ds = BasketDataset { name, m, baskets };
        ds.validate()?;
        Ok(ds)
    }
}

/// Pad/truncate baskets into a `(bsz x kmax)` i32 buffer (pad = -1) for the
/// AOT train/eval graphs.
pub fn pad_batch(baskets: &[Vec<usize>], kmax: usize) -> Vec<i32> {
    let mut out = vec![-1i32; baskets.len() * kmax];
    for (r, b) in baskets.iter().enumerate() {
        for (c, &i) in b.iter().take(kmax).enumerate() {
            out[r * kmax + c] = i as i32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> BasketDataset {
        BasketDataset::new(
            "toy",
            10,
            vec![vec![0, 1, 2], vec![3, 4], vec![5], vec![6, 7, 8, 9], vec![0, 5]],
        )
    }

    #[test]
    fn validation_catches_problems() {
        let mut bad = fixture();
        bad.baskets.push(vec![10]);
        assert!(bad.validate().is_err());
        let mut dup = fixture();
        dup.baskets.push(vec![1, 1]);
        assert!(dup.validate().is_err());
    }

    #[test]
    fn frequencies_and_sizes() {
        let ds = fixture();
        let mu = ds.item_frequencies();
        assert_eq!(mu[0], 2.0);
        assert_eq!(mu[1], 1.0);
        assert_eq!(mu[9], 1.0);
        assert_eq!(ds.max_basket_size(), 4);
        assert!((ds.mean_basket_size() - 2.4).abs() < 1e-12);
    }

    #[test]
    fn split_partitions() {
        let ds = fixture();
        let mut rng = Xoshiro::seeded(1);
        let s = ds.split(1, 2, &mut rng);
        assert_eq!(s.val.len(), 1);
        assert_eq!(s.test.len(), 2);
        assert_eq!(s.train.len(), 2);
    }

    #[test]
    fn save_load_roundtrip() {
        let ds = fixture();
        let path = std::env::temp_dir().join(format!("ndpp_ds_{}.txt", std::process::id()));
        ds.save(&path).unwrap();
        let back = BasketDataset::load(&path).unwrap();
        assert_eq!(back.m, ds.m);
        assert_eq!(back.baskets, ds.baskets);
        assert_eq!(back.name, "toy");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn pad_batch_layout() {
        let batch = pad_batch(&[vec![1, 2], vec![3, 4, 5, 6, 7]], 4);
        assert_eq!(batch, vec![1, 2, -1, -1, 3, 4, 5, 6]);
    }

    #[test]
    fn trim_drops_large_and_empty() {
        let mut ds = fixture();
        ds.baskets.push(vec![]);
        ds.trim(3);
        assert!(ds.baskets.iter().all(|b| !b.is_empty() && b.len() <= 3));
    }
}
