//! Synthetic basket generation.
//!
//! The generator produces recommendation-style basket data with the three
//! statistical properties the paper's real datasets exhibit and that the
//! NDPP machinery is sensitive to:
//!
//! 1. **power-law item popularity** (Zipf weights within clusters),
//! 2. **positive co-occurrence** (items from the same latent cluster appear
//!    together — what the *nonsymmetric* kernel part models),
//! 3. **intra-basket diversity** (no duplicates; baskets mix a dominant
//!    cluster with background items — what the symmetric part models).
//!
//! Basket sizes are `1 + Poisson(mean_size - 1)`, truncated at `max_size`
//! (the paper trims at 100).

use crate::data::baskets::BasketDataset;
use crate::rng::Xoshiro;

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct BasketGenConfig {
    pub name: String,
    /// catalog size M
    pub m: usize,
    pub n_baskets: usize,
    /// mean basket size (>= 1)
    pub mean_size: f64,
    pub max_size: usize,
    /// number of latent co-occurrence clusters
    pub clusters: usize,
    /// Zipf exponent for within-cluster item popularity
    pub zipf_s: f64,
    /// probability that an item is drawn from the background (uniform over
    /// the catalog) instead of the basket's dominant cluster
    pub background_prob: f64,
}

impl Default for BasketGenConfig {
    fn default() -> Self {
        BasketGenConfig {
            name: "synthetic".into(),
            m: 1000,
            n_baskets: 2000,
            mean_size: 6.0,
            max_size: 100,
            clusters: 50,
            zipf_s: 1.0,
            background_prob: 0.25,
        }
    }
}

/// Generate a basket dataset.
pub fn generate_baskets(cfg: &BasketGenConfig, rng: &mut Xoshiro) -> BasketDataset {
    assert!(cfg.m >= 2 && cfg.clusters >= 1 && cfg.mean_size >= 1.0);
    let clusters = cfg.clusters.min(cfg.m);
    // items round-robin assigned to clusters => cluster c owns items
    // {c, c + clusters, ...}; popularity within a cluster is Zipf over rank.
    let items_per_cluster = cfg.m.div_ceil(clusters);
    // precompute zipf weights per rank
    let zipf: Vec<f64> = (0..items_per_cluster)
        .map(|r| 1.0 / ((r + 1) as f64).powf(cfg.zipf_s))
        .collect();

    let mut baskets = Vec::with_capacity(cfg.n_baskets);
    for _ in 0..cfg.n_baskets {
        let size = (1 + rng.poisson((cfg.mean_size - 1.0).max(0.0)) as usize)
            .min(cfg.max_size)
            .min(cfg.m);
        let dominant = rng.below(clusters);
        let mut basket: Vec<usize> = Vec::with_capacity(size);
        let mut guard = 0;
        while basket.len() < size && guard < 50 * size {
            guard += 1;
            let item = if rng.uniform() < cfg.background_prob {
                rng.below(cfg.m)
            } else {
                // rank within the dominant cluster by zipf weight
                let rank = rng.weighted(&zipf);
                let item = dominant + rank * clusters;
                if item >= cfg.m {
                    continue;
                }
                item
            };
            if !basket.contains(&item) {
                basket.push(item);
            }
        }
        basket.sort_unstable();
        baskets.push(basket);
    }
    BasketDataset::new(cfg.name.clone(), cfg.m, baskets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_config() {
        let cfg = BasketGenConfig { m: 200, n_baskets: 300, mean_size: 5.0, ..Default::default() };
        let mut rng = Xoshiro::seeded(1);
        let ds = generate_baskets(&cfg, &mut rng);
        assert_eq!(ds.m, 200);
        assert_eq!(ds.baskets.len(), 300);
        ds.validate().unwrap();
        let mean = ds.mean_basket_size();
        assert!((mean - 5.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn popularity_is_skewed() {
        let cfg = BasketGenConfig {
            m: 100,
            n_baskets: 2000,
            clusters: 10,
            background_prob: 0.1,
            ..Default::default()
        };
        let mut rng = Xoshiro::seeded(2);
        let ds = generate_baskets(&cfg, &mut rng);
        let mu = ds.item_frequencies();
        // head items (first rank of each cluster: ids 0..10) should be much
        // more popular than tail items
        let head: f64 = mu[..10].iter().sum();
        let tail: f64 = mu[90..].iter().sum();
        assert!(head > 2.0 * tail, "head={head} tail={tail}");
    }

    #[test]
    fn cooccurrence_structure_present() {
        // items of the same cluster co-occur more than cross-cluster pairs
        let cfg = BasketGenConfig {
            m: 60,
            n_baskets: 4000,
            clusters: 6,
            mean_size: 4.0,
            background_prob: 0.1,
            ..Default::default()
        };
        let mut rng = Xoshiro::seeded(3);
        let ds = generate_baskets(&cfg, &mut rng);
        let mut same = 0.0;
        let mut cross = 0.0;
        for b in &ds.baskets {
            for i in 0..b.len() {
                for j in (i + 1)..b.len() {
                    if b[i] % 6 == b[j] % 6 {
                        same += 1.0;
                    } else {
                        cross += 1.0;
                    }
                }
            }
        }
        // under independence same-cluster pairs are ~1/6 of all pairs
        assert!(same / (same + cross) > 0.3, "same={same} cross={cross}");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = BasketGenConfig::default();
        let a = generate_baskets(&cfg, &mut Xoshiro::seeded(7));
        let b = generate_baskets(&cfg, &mut Xoshiro::seeded(7));
        assert_eq!(a.baskets, b.baskets);
    }
}
