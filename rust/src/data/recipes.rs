//! The five benchmark dataset stand-ins (DESIGN.md §4).
//!
//! Catalog sizes mirror the paper's Table 2/3 datasets; basket counts and
//! sizes are scaled to keep the end-to-end experiment suite runnable on a
//! laptop while preserving the ordering `M_1 < ... < M_5` and the
//! popularity/co-occurrence structure the learners are sensitive to.
//! Two "fidelity" profiles exist: `fast` (default, used by tests and
//! `ndpp reproduce`) and `paper` (full catalog sizes, for Table 3's
//! large-M timing points).

use crate::data::baskets::BasketDataset;
use crate::data::synthetic::{generate_baskets, BasketGenConfig};
use crate::rng::Xoshiro;

/// A named dataset recipe (generator parameters + paper metadata).
#[derive(Debug, Clone)]
pub struct DatasetRecipe {
    pub name: &'static str,
    /// the paper's real-dataset catalog size
    pub paper_m: usize,
    /// generation config (fast profile)
    pub config: BasketGenConfig,
}

/// All five stand-ins, ordered by catalog size.
pub fn standard_datasets(profile: &str) -> Vec<DatasetRecipe> {
    let paper = profile == "paper";
    let scale = |m_paper: usize, m_fast: usize| if paper { m_paper } else { m_fast };
    let baskets = |n_paper: usize, n_fast: usize| if paper { n_paper } else { n_fast };
    vec![
        DatasetRecipe {
            name: "uk_retail_synth",
            paper_m: 3_941,
            config: BasketGenConfig {
                name: "uk_retail_synth".into(),
                m: scale(3_941, 3_941),
                n_baskets: baskets(19_762, 3_000),
                mean_size: 8.0,
                clusters: 120,
                ..Default::default()
            },
        },
        DatasetRecipe {
            name: "recipe_synth",
            paper_m: 7_993,
            config: BasketGenConfig {
                name: "recipe_synth".into(),
                m: scale(7_993, 7_993),
                n_baskets: baskets(178_265, 4_000),
                mean_size: 9.0,
                clusters: 200,
                ..Default::default()
            },
        },
        DatasetRecipe {
            name: "instacart_synth",
            paper_m: 49_677,
            config: BasketGenConfig {
                name: "instacart_synth".into(),
                m: scale(49_677, 49_677),
                n_baskets: baskets(100_000, 5_000),
                mean_size: 10.0,
                clusters: 600,
                ..Default::default()
            },
        },
        DatasetRecipe {
            name: "song_synth",
            paper_m: 371_410,
            config: BasketGenConfig {
                name: "song_synth".into(),
                m: scale(371_410, 131_072),
                n_baskets: baskets(200_000, 5_000),
                mean_size: 12.0,
                clusters: 1_500,
                ..Default::default()
            },
        },
        DatasetRecipe {
            name: "book_synth",
            paper_m: 1_059_437,
            config: BasketGenConfig {
                name: "book_synth".into(),
                m: scale(1_059_437, 262_144),
                n_baskets: baskets(200_000, 5_000),
                mean_size: 12.0,
                clusters: 3_000,
                ..Default::default()
            },
        },
    ]
}

/// Find a recipe by name.
pub fn dataset_by_name(name: &str, profile: &str) -> Option<DatasetRecipe> {
    standard_datasets(profile).into_iter().find(|r| r.name == name)
}

impl DatasetRecipe {
    /// Generate the dataset deterministically (seed derived from the name).
    pub fn generate(&self, seed: u64) -> BasketDataset {
        let mut h = seed;
        for b in self.name.bytes() {
            h = h.wrapping_mul(31).wrapping_add(b as u64);
        }
        let mut rng = Xoshiro::seeded(h);
        generate_baskets(&self.config, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_datasets_ordered_by_m() {
        let ds = standard_datasets("fast");
        assert_eq!(ds.len(), 5);
        for w in ds.windows(2) {
            assert!(w[0].config.m < w[1].config.m);
            assert!(w[0].paper_m < w[1].paper_m);
        }
    }

    #[test]
    fn paper_profile_uses_paper_m() {
        let ds = standard_datasets("paper");
        assert_eq!(ds[0].config.m, 3_941);
        assert_eq!(ds[4].config.m, 1_059_437);
    }

    #[test]
    fn lookup_and_generate() {
        let r = dataset_by_name("uk_retail_synth", "fast").unwrap();
        let ds = r.generate(0);
        assert_eq!(ds.m, 3_941);
        assert_eq!(ds.baskets.len(), 3_000);
        ds.validate().unwrap();
        // deterministic
        let ds2 = r.generate(0);
        assert_eq!(ds.baskets[..50], ds2.baskets[..50]);
        assert!(dataset_by_name("nope", "fast").is_none());
    }
}
