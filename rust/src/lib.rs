//! # ndpp — Scalable Sampling for Nonsymmetric Determinantal Point Processes
//!
//! Production-oriented reproduction of Han, Gartrell, Gillenwater, Dohmatob,
//! Karbasi, *"Scalable Sampling for Nonsymmetric Determinantal Point
//! Processes"* (ICLR 2022) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: NDPP kernel algebra,
//!   the linear-time Cholesky-based sampler (paper §3), the sublinear
//!   tree-based rejection sampler (paper §4), the fixed-size MCMC up-down
//!   sampler (after the follow-up *Scalable MCMC Sampling for NDPPs*, Han
//!   et al. 2022), ONDPP learning (paper §5), a batching sampling service,
//!   datasets, metrics, and the benchmark harness regenerating every
//!   table/figure of the paper's evaluation.
//! * **Layer 2 (python/compile)** — JAX graphs (marginal kernel, scan-based
//!   Cholesky sweep, ONDPP train step) AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels)** — Pallas kernels for the
//!   `O(M K^2)` item-axis hot spots.
//!
//! The rust binary is self-contained once `make artifacts` has produced the
//! HLO artifacts; python never runs on the request path.  Every XLA-backed
//! op also has a pure-rust fallback, so the library degrades gracefully
//! when artifacts are absent (and the two paths cross-check each other in
//! the test suite and the `ablation` bench).
//!
//! ## Quickstart
//!
//! ```no_run
//! use ndpp::prelude::*;
//!
//! // A random ONDPP kernel over M = 1000 items with rank 2K = 32.
//! let mut rng = Xoshiro::seeded(7);
//! let kernel = NdppKernel::random_ondpp(1000, 16, &mut rng);
//!
//! // Linear-time exact sampler (paper Algorithm 1, right-hand side).
//! let mut cholesky = CholeskySampler::new(&kernel);
//! let sample = cholesky.sample(&mut rng);
//!
//! // Sublinear tree-based rejection sampler (paper Algorithm 2).
//! let proposal = Proposal::build(&kernel);
//! let spectral = proposal.spectral();
//! let tree = SampleTree::build(&spectral, TreeConfig::default());
//! let mut rejection = RejectionSampler::new(&kernel, &proposal, &tree);
//! let sample2 = rejection.sample(&mut rng);
//!
//! // Fixed-size (k-NDPP) MCMC up-down sampler — use when
//! // `proposal.expected_rejections()` diverges (relaxed orthogonality /
//! // unregularized sigmas): O(k^2 + kK) per chain step, independent of
//! // both M and the rejection rate.  Attaching the prepared tree turns
//! // the uniform candidate oracle into the tree-driven proposal: each
//! // candidate is drawn in O(log M) descent proportional to its
//! // conditioned marginal weight, so far fewer Metropolis steps are
//! // wasted on near-zero-weight items.
//! let mut mcmc =
//!     McmcSampler::new(&kernel, McmcConfig::for_kernel(&kernel)).with_tree(&tree);
//! let sample3 = mcmc.sample(&mut rng);
//!
//! // Variable-size up/down/swap chain — same per-step costs, but targets
//! // the FULL law Pr(Y) ∝ det(L_Y), cardinality included: the drop-in
//! // MCMC replacement wherever rejection sampling is the semantic target
//! // but its proposal count U has diverged.
//! let mut var =
//!     VariableMcmcSampler::new(&kernel, McmcConfig::for_kernel(&kernel)).with_tree(&tree);
//! let sample4 = var.sample(&mut rng);
//! # let _ = (sample, sample2, sample3, sample4);
//! ```
//!
//! ## Choosing a sampler
//!
//! * [`CholeskySampler`](sampler::CholeskySampler) — exact, `O(M K^2)` per
//!   sample; the default for one-off sampling at moderate `M`.
//! * [`RejectionSampler`](sampler::RejectionSampler) — exact and sublinear
//!   in `M`, but pays `U = det(L̂+I)/det(L+I)` proposal draws per sample;
//!   only viable for (near-)ONDPP kernels with regularized sigmas, where
//!   Theorem 2 bounds `U` independently of `M`.
//! * [`McmcSampler`](sampler::McmcSampler) — fixed-size (k-NDPP) chain,
//!   approximate with controllable burn-in/thinning; per-step cost
//!   `O(k^2 + kK)` no matter how large `U` gets.  Prefer it when
//!   `Proposal::expected_rejections()` is large (rule of thumb: over a few
//!   hundred) or when the workload wants exactly-k-item samples.
//! * [`VariableMcmcSampler`](sampler::VariableMcmcSampler) — the
//!   variable-size up/down/swap chain over the **full** law
//!   `Pr(Y) ∝ det(L_Y)`, cardinality included; what `algo=auto` steering
//!   falls through to when a basket's conditional rejection rate
//!   diverges, because it targets the same distribution rejection would
//!   have sampled.
//!
//! Both chains draw their candidate items through the prepared
//! [`SampleTree`](sampler::SampleTree) by default
//! ([`ProposalKind::Tree`](sampler::ProposalKind), `with_tree`): one
//! `O(log M)` descent proposes item `j` with probability proportional to
//! its conditioned marginal weight (ε-mixed with uniform for
//! irreducibility), and the exact descent probability feeds the
//! Metropolis correction, so detailed balance is preserved while far
//! fewer steps self-loop on zero-weight candidates than under the
//! uniform oracle — the win grows with catalog size and marginal skew.
//! Pin `ProposalKind::Uniform` (`--mcmc-proposal uniform`,
//! `McmcConfig.proposal`) to recover the classical chain; burn-in adapts
//! online from the log-det trajectory's autocorrelation
//! (`McmcConfig.adaptive_burn_in`) and both chains expose restart mode
//! (independent samples) and thinned chain mode (`sample_chain`, the wire
//! `chain: true` flag) plus acceptance-rate/step telemetry.
//! * [`DenseCholeskySampler`](sampler::DenseCholeskySampler) — the dense
//!   `O(M^3)` baseline, exposed end to end (`SamplerKind::Dense`, service
//!   dispatch, wire protocol, CLI `--algo dense`) for small-M debugging
//!   and conformance runs.
//!
//! ## Choosing a compute backend
//!
//! Every GEMM-shaped hot path — marginal-kernel and proposal Gram
//! matrices, spectral lifting, tree node statistics, QR panel updates, the
//! incremental-minor refreshes — routes through the pluggable
//! [`linalg::backend`] layer:
//!
//! * `blocked` (default) — cache-blocked kernels, with large products
//!   fanned out over row bands on a persistent compute pool
//!   ([`linalg::pool`]).
//! * `simd` — the blocked panelization and pool threading, plus a packed
//!   `B`-panel layout and explicit vector microkernels in the inner
//!   loops.  Each `KC`-deep panel of `B` is packed once per band into a
//!   per-thread scratch buffer (reused across panels — no steady-state
//!   allocation) in exactly the order the microkernel consumes it, so
//!   the inner FMA loop streams unit-stride loads.  The instruction set
//!   is probed **at runtime** (`is_x86_feature_detected!`) across four
//!   tiers: AVX-512F (8-wide lanes) → AVX2+FMA (4-wide) on x86_64, NEON
//!   on aarch64, and portable 4-wide unrolled lanes everywhere else, so
//!   selecting `simd` is always safe — `ndpp info` and the
//!   `BENCH_linalg.json` `isa` field report what was actually detected,
//!   and `NDPP_SIMD_ISA=portable|avx2|avx512|neon` forces a tier for
//!   testing.  Pick `simd` when sampler preprocessing (model
//!   registration, Gram / spectral / tree construction) dominates; pick
//!   `blocked` when you need the exact numerics CI's default leg runs;
//!   `naive` is for debugging only.
//! * `naive` — the single-threaded reference loops, kept as the
//!   correctness oracle the fast kernels are property-tested against
//!   (`tests/backend_equivalence.rs`).
//!
//! Select per process with `NDPP_BACKEND=naive|blocked|simd`,
//! programmatically with [`linalg::backend::set_active`], per deployment
//! with [`coordinator::ServiceConfig`]'s `backend` field, or per CLI run
//! with `--backend`.  `cargo bench --bench linalg_backends` sweeps all
//! three backends over GEMM shapes (packed vs unpacked, pool vs
//! spawn-per-call, serving interference) and end-to-end registry
//! preprocessing and writes `BENCH_linalg.json`.
//!
//! **Thread budget.**  One core inventory drives every knob:
//! [`linalg::backend::thread_budget`] resolves
//! `available_parallelism`, applies `NDPP_BACKEND_THREADS` (if set),
//! and derives the split the rest of the system uses — `t` threads per
//! backend op means a persistent pool of `t - 1` parked workers plus
//! the calling thread, and when `t` is pinned below the core count the
//! remaining `cores - t` cores become the default serving-shard count.
//! `ndpp info`, the wire-protocol `models`/`metrics` ops, and
//! `BENCH_linalg.json` all record the resolved budget.
//!
//! **Reading `BENCH_trajectory.json`.**  CI merges `BENCH_linalg.json`
//! and `BENCH_serving.json` into one `BENCH_trajectory.json` artifact per
//! commit (`scripts/bench_gate.py`, which also *fails* the build when
//! blocked-vs-naive GEMM speedup at 512³ drops below 2x, simd-vs-blocked
//! below 1.4x, packed-vs-unpacked below 1.15x, any pool-vs-spawn row
//! below 1.0x, or any serving config collapses to 0 req/s).  Inside it,
//! `linalg.gemm[*]` rows carry `naive_s` / `blocked_s` / `simd_s` /
//! `simd_unpacked_s` wall times plus `speedup` (naive/blocked),
//! `simd_vs_blocked`, and `packed_vs_unpacked`; `linalg.pool[*]` rows
//! compare the persistent pool against spawn-per-call fan-out on skinny
//! panel shapes; `linalg.interference` times a 512³ GEMM while a
//! saturating serving load runs on the same budget; `linalg.isa` records
//! the detected instruction set (gates on the simd and packed columns
//! are relaxed when it reports `portable`); `serving.sweep[*]` rows
//! carry `requests_per_s` and latency percentiles per
//! (algorithm × client-count) config; `serving.mcmc_mixing[*]` rows
//! compare the tree-driven proposal against the uniform oracle — burn-in
//! `steps_to_tv` against an enumerated law, `acceptance`, and steered
//! closed-loop `steered_requests_per_s` — and the gate fails if the tree
//! proposal needs more burn-in than uniform or any steered config serves
//! nothing.
//!
//! ## Conditional sampling / basket completion
//!
//! The predictive workload behind NDPPs (paper §6.1) is next-item and
//! basket completion: reason about `Y ⊇ J` for an observed partial basket
//! `J`.  The [`ndpp::conditional`] module reduces conditioning to a
//! `2K x 2K` Schur complement `G_J = X − X Z_J^T L_J^{-1} Z_J X`
//! (`O(|J| K^2 + |J|^3)`, no `M`-sized work), and
//! [`sampler::conditional`] drives all three fast sampler families from
//! it:
//!
//! * **Conditional Cholesky** (`algo=cholesky` + `given`) — exact,
//!   `O(M K^2)`: the conditioned marginal `W_J = G_J (I + Gram·G_J)^{-1}`
//!   uses the registration-time catalog Gram, then the standard sweep
//!   skips `J`.  With `given=[]` it is byte-identical to the
//!   unconditional sampler.  The default: always correct, linear time.
//! * **Conditional rejection** (`algo=rejection` + `given`) — sublinear:
//!   the prepared [`sampler::SampleTree`]'s node statistics are sums of
//!   `v_j v_j^T` that do not depend on the kernel's inner matrix, so a
//!   conditioned proposal reuses the tree **verbatim**; per request only
//!   an `R x R` eigendecomposition is rebuilt (sym part + polar of the
//!   skew part of `G_J`, expressed in the prepared eigenbasis).  Prefer
//!   it when `M` is large and the conditional expected rejection count
//!   ([`sampler::ConditionalScratch::expected_rejections`]) stays small;
//!   note conditioning can grow `U` beyond the unconditional Theorem 2
//!   bound, so check it per basket — the serving pipeline measures it per
//!   request and, under `algo=auto`, *steers* infeasible baskets to the
//!   conditional MCMC chain instead of refusing them (see *Request
//!   economics* below).
//! * **Conditional fixed-size MCMC** (`algo=mcmc` + `given`) — an
//!   [`ndpp::probability::IncrementalMinor`] seeded from `J` plus a
//!   deterministic greedy completion; the up-down chain swaps only
//!   non-`J` positions.  Use it when the conditional rejection rate
//!   diverges.
//!
//! On the wire, every `sample` / `batch` entry takes a `given: [items]`
//! field (validated per request: distinct, `< M`, `|given| <= 2K`,
//! nonsingular `L_J`; errors answer that entry alone).  The `models` op
//! reports each model's conditioning audit (`max_given = 2K`, supported
//! samplers).  CLI: `ndpp sample --given 3,17,42`, and `ndpp complete`
//! ranks top next-item scores alongside sampled sets.  Scoring
//! (`learn::eval`'s MPR/AUC) consumes the same
//! [`ndpp::ConditionedKernel`], so serving and evaluation can never
//! drift.  See `examples/basket_completion.rs` for the full walkthrough.
//!
//! ## Request economics: steering and the conditioning cache
//!
//! Two per-request costs dominate conditional serving, and the pipeline
//! manages both so clients can default to `algo=auto` and forget about
//! them:
//!
//! * **Proposal cost (steering).**  A conditioned rejection run pays
//!   `U_J = exp(log det(L̂_J + I) − log det(L_J + I))` proposal draws per
//!   sample, and `U_J` is a per-basket quantity that conditioning can
//!   push far past the unconditional Theorem 2 bound.  The service
//!   computes it before sampling; when it exceeds
//!   [`coordinator::ServiceConfig`]'s `steer_threshold` (default `1e4`,
//!   `--steer-threshold` on `ndpp serve`), an `algo=auto` request — the
//!   wire default whenever `given` is present — silently falls through
//!   to the conditional **variable-size** MCMC chain, whose per-step cost
//!   is independent of `U_J` and whose stationary law is the same
//!   `Pr(Y | J ⊆ Y)` the rejection sampler targets, so steering is
//!   invisible in distribution (pinned `tests/conditional.rs`
//!   `steering_` conformance).  Only a client that *pinned*
//!   `algo=rejection` gets the structured infeasibility error.  The
//!   chain's candidate items come from the model's prepared tree
//!   (restricted to the conditioned basis) unless the deployment pins
//!   `--mcmc-proposal uniform`; a request with `chain: true` and `n > 1`
//!   opts into one thinned trajectory instead of `n` independent
//!   restarts (cheaper by ~`burn_in/thinning`, successive samples
//!   correlated).  Every response reports the sampler that actually ran
//!   (`algo`), the rejection-family paths add the measured
//!   `expected_rejections`, and the MCMC paths add an `mcmc` block
//!   (`proposal`, `steps`, `acceptance`, `chain`).  Decisions are
//!   counted per model (`auto_rejection` / `auto_mcmc` /
//!   `refused_infeasible`) in the `metrics` op and the `models` audit,
//!   which also carry per-proposal chain counters
//!   (requests/steps/acceptance) and the active chain config.
//! * **Conditioning cost (the hot-basket cache).**  Building a
//!   conditioned sampler costs a `2K x 2K` Schur complement plus, on the
//!   rejection path, an `R x R` eigendecomposition — per request.  Real
//!   basket-completion traffic is Zipf-shaped (a handful of popular
//!   carts dominates), so the service keeps a per-model LRU of immutable
//!   [`sampler::conditional::ConditionedState`]s keyed by the canonical
//!   (sorted) basket, bounded by [`coordinator::ServiceConfig`]'s
//!   `conditioning_cache_bytes` (default 64 MiB; `--cache-bytes`, `0`
//!   disables).  Given-bearing requests are routed to their shard by a
//!   hash of `(model, basket)` — not round robin — so repeat baskets
//!   land where their state is warm.  The cache is
//!   **sampling-transparent**: cached states hold only RNG-free
//!   conditioning products, so any request stream returns byte-identical
//!   samples with the cache on, off, or thrashing (the `cache_`-prefixed
//!   suites in `tests/conditional.rs` pin this).  Occupancy and
//!   effectiveness (`hits` / `misses` / `evictions` / `bytes` vs
//!   `budget`) are exported in the `metrics` op, per model in the
//!   `models` audit, and swept by `cargo bench --bench serving` (the
//!   `cache[]` rows `scripts/bench_gate.py` gates on).
//!
//! ## Serving at scale
//!
//! [`coordinator::SamplingService`] is a sharded pipeline built on the
//! samplers' Prepared/Scratch split (see [`sampler`]): model registration
//! freezes all preprocessing into an immutable, `Send + Sync`
//! [`coordinator::ModelEntry`], and each of the service's shard workers
//! keeps its own warm scratch per model — so concurrent throughput scales
//! with shard count, with no locking and no per-call allocation on the
//! sampler hot paths.  `ndpp serve` exposes every knob
//! (`--shards --queue-depth --deadline-ms --backend`); see
//! `examples/serve_shards.rs` for a walkthrough.
//!
//! **Shard sizing.** `ServiceConfig::shards == 0` resolves via
//! [`coordinator::default_shards`], which reads the same
//! [`linalg::backend::thread_budget`] split as the compute pool: one
//! worker per core by default, minus the cores explicitly reserved for
//! GEMM fan-out when `NDPP_BACKEND_THREADS` is pinned below the core
//! count (registration-time preprocessing is the only GEMM-threaded
//! phase; steady-state sampling is single-threaded per shard).  The
//! resolved split is visible in `ndpp info` and the `models`/`metrics`
//! wire ops.  Rule of thumb: CPU-bound sampling wants `shards = cores`;
//! deployments that re-register models under live traffic should leave
//! the backend 1–2 cores.
//!
//! **Admission control.** Each `(model, shard)` queue is bounded by
//! `ServiceConfig::queue_depth`; an overflowing submission fails
//! *immediately* with a `queue_full` error rather than buffering
//! unboundedly — callers retry with backoff or shed load.  A request may
//! carry a `deadline` (`deadline_ms` on the wire, with
//! `ServiceConfig::deadline` as the default): a worker that dequeues an
//! expired request discards it with a `deadline` error instead of doing
//! dead work.  Both outcomes are counted per model under `rejected` in
//! the metrics snapshot, and neither poisons neighboring requests.
//! Dropping the service stops intake (`shutting_down` errors), then
//! drains every queued request before the workers exit.
//!
//! **Reproducibility contract.** A request's samples are drawn from
//! [`rng::request_stream`]`(seed)` — a pure function of the request seed.
//! Same `(model, seed, n, algo)` ⇒ byte-identical samples, regardless of
//! shard count, shard assignment, batch composition (single `sample` ops
//! vs one `batch` op), concurrency, or service instance.  Omitted seeds
//! are assigned from a counter and returned in the response, so every
//! response is replayable.  `cargo bench --bench serving` runs a
//! closed-loop multi-client sweep and writes `BENCH_serving.json`.
//!
//! ## Model lifecycle: train → canary → hot-swap → rollback
//!
//! Models in the registry are **versioned**: every
//! [`coordinator::SamplingService::register`] of an existing name
//! prepares a *new* immutable version behind the same mutable alias
//! (never a silent replacement), and the whole family stays addressable —
//! bare `"books"` resolves the live version, `"books@3"` pins one
//! forever.  The rollout verbs:
//!
//! * **Train** — `ndpp train` learns an ONDPP kernel from baskets
//!   ([`learn::Trainer`] over the AOT graph, or the artifact-free
//!   [`learn::NativeTrainer`] on a bare host) and checkpoints it with
//!   `--out`.
//! * **Canary** — [`coordinator::SamplingService::register_candidate`]
//!   (wire `register` with `canary: true`, CLI
//!   `ndpp promote --kernel … --stage-only`) stages the new version; with
//!   [`coordinator::ServiceConfig`]'s `canary_fraction > 0` a
//!   **deterministic, seed-hashed** slice of bare-alias traffic serves
//!   from it (replay-stable: the same request seed always lands on the
//!   same side), stamped `canary: true` and split out per version in the
//!   `metrics` op.
//! * **Promote** — [`coordinator::SamplingService::promote_gated`] (wire
//!   `promote` with `data`, CLI `ndpp promote --data …`) scores candidate
//!   and live on held-out MPR/AUC and refuses a regressing candidate;
//!   the same gate runs in CI over the bench trajectory artifact
//!   (`scripts/bench_gate.py`, `lifecycle.eval[]` rows).  Promotion is an
//!   **atomic alias move**: requests resolve their version once at
//!   admission, so in-flight work finishes on the version it resolved
//!   while the displaced version's conditioning-cache entries and warm
//!   per-shard scratches are retired immediately (`retired` cache
//!   counter) — zero dropped requests, pinned by `tests/lifecycle.rs`
//!   under concurrent load.
//! * **Rollback** — [`coordinator::SamplingService::rollback`] (wire
//!   `rollback`, CLI `ndpp rollback`) moves the alias back one version;
//!   replays against the restored version are byte-identical to before
//!   the swap.
//!
//! `examples/lifecycle_rollout.rs` walks the full cycle end to end; the
//! operator's runbook lives in `docs/OPERATIONS.md` and the complete wire
//! reference in `docs/PROTOCOL.md` (kept op-complete by
//! `scripts/check_protocol_doc.py` in CI).

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod learn;
pub mod linalg;
pub mod ndpp;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod util;

/// Convenient re-exports of the main public types.
pub mod prelude {
    pub use crate::linalg::{BackendKind, Matrix};
    pub use crate::ndpp::{ConditionedKernel, NdppKernel, Proposal};
    pub use crate::rng::Xoshiro;
    pub use crate::sampler::{
        CholeskySampler, ConditionalPrepared, ConditionalScratch, DenseCholeskySampler,
        McmcConfig, McmcSampler, ProposalKind, RejectionSampler, SampleTree, Sampler, TreeConfig,
        VariableMcmcSampler,
    };
}

/// Crate-wide error type.
pub type Error = anyhow::Error;
/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
